//! Multi-tenant serving engine — N resident models behind one four-party
//! cluster, scheduled by the [`crate::sched`] subsystem.
//!
//! The single-tenant engine (`serve::serve`) runs one model, one keyed
//! pool, one FIFO queue. This engine runs one **engine instance per
//! resident model** over shared parties: the [`ModelRegistry`] loads every
//! tenant's weights and registers a per-tenant [`CircuitKey`] (the keyed
//! pool shards by the key's `model` field, so tenant material never
//! crosses — a wrong-tenant pop fails closed); the [`SchedQueue`] admits
//! tenant-tagged queries under per-tenant in-flight caps and orders them
//! by priority class + EDF with aging; the [`WavePlanner`] grants waves by
//! weighted round-robin across the tenants eligible at the best class;
//! and between waves one refill tick tops up the **most-depleted** tenant
//! pool that can still consume a full wave.
//!
//! Everything the scheduler decides is driven by logical ticks and public
//! metadata — lockstep-deterministic across the four party threads (the
//! [`crate::sched`] module docs explain why wall-clock is banned here).
//! Per-wave protocol execution runs the tenant's **whole resident
//! network**: stack the batch, then one `Π_MatMulTr` per layer against
//! that layer's resident weights — hidden layers ReLU-activated, the
//! head linear — and verified reconstruction towards the data owner. A
//! keyed wave pops the tenant's **per-layer bundle vector** all-or-
//! nothing: every gate's paired bundle must be in stock (the trailing
//! partial wave has its own per-layer vector, registered at load and
//! warmed once), else the entire wave takes the deterministic inline
//! fallback — layer ℓ ≥ 1 re-masks the shared activation under the
//! popped `Λ_X` via the δ-open of [`crate::proto::sharing::remask_mat`],
//! so a warm deep wave is offline-silent at every gate.
//!
//! With `containment: true`, every keyed wave body is wrapped in the
//! abort-blast-radius boundary: on a failure the four parties agree over
//! [`crate::net::PartyCtx::wave_barrier`] whether the blast radius is one
//! tenant's keyed material (→ quarantine the tenant, re-admit the wave's
//! queries, keep serving) or the run itself (→ fail closed, exactly the
//! paper's contract — see the abort-scoping contract in [`crate::net`]).
//!
//! Nonlinear material is tenant-sharded too: a `relu: true` tenant's
//! bit-extraction masks, `⟨γ_{r·v}⟩` and `Π_BitInj` correlations live in
//! [`crate::pool::relu::ReluCorr`] bundles under the tenant's own
//! `OpKind::Relu` circuit key, generated **paired** with the matrix
//! bundles — so per-tenant offline budgets are exact, a cross-tenant pop
//! fails closed, and a warm keyed wave is offline-silent through the
//! whole pipeline, ReLU included (the per-op counters
//! `offline_msgs_matmul` / `offline_msgs_relu` attribute the claim).

use crate::crypto::Rng;
use crate::ml::nn::{forward_keyed, train_gate_keys, train_step, HeadActivation};
use crate::ml::{share_fixed_mat, F64Mat};
use crate::net::{Abort, NetProfile, NetReport, PartyId, Phase, P2};
use crate::obs::{self, Payload, TraceEvent, Window};
use crate::pool::{relu_key_for, Pool, PoolStats};
use crate::proto::{
    matmul_tr, reconstruct_mat_backend, reconstruct_mat_to_backend, run_4pc, Backend, Ctx,
};
use crate::ring::fixed::FixedPoint;
use crate::ring::{Matrix, Z64};
use crate::sched::{
    tenant_layer_key, tenant_layer_weights, Checkpoint, ModelRegistry, SchedQueue,
    SchedQueueStats, SchedQuery, TenantSpec, TrainKind, WavePlanner,
};
use crate::sharing::MMat;
use super::PoolMode;

/// Domain separator for per-tenant query streams.
const TQ_SEED: u64 = 0x7363_6864_5f71_3174;

/// Domain separator for per-tenant training batches.
const TT_SEED: u64 = 0x7472_6169_6e5f_3974;

/// Multi-tenant serving workload.
#[derive(Clone, Debug)]
pub struct MultiServeConfig {
    pub tenants: Vec<TenantSpec>,
    /// `Inline` (seed-style per-wave offline) or `Keyed` (per-tenant
    /// circuit-keyed pools). `Scalar` is not meaningful per tenant.
    pub mode: PoolMode,
    /// Per-tenant refill low-water mark, in full-wave keyed bundles.
    pub low_water: usize,
    /// Per-tenant refill high-water mark, same units.
    pub high_water: usize,
    /// Aging rule: promote a waiting query one priority class per this
    /// many ticks (0 = off). See [`crate::sched::queue`].
    pub age_every: u64,
    pub seed: u64,
    /// Abort blast-radius containment: when a keyed wave fails and the
    /// four-party wave barrier agrees the blast radius is one tenant's
    /// keyed material, quarantine that tenant (drain-and-poison its pool
    /// shards, stop its refills) and keep serving everyone else — the
    /// wave's queries are re-admitted with their original arrival ticks.
    /// Party-scoped aborts (and keyed failures that interrupted inline
    /// generation) still fail the whole run closed. Off by default: any
    /// abort is run-fatal, the pre-containment behaviour.
    pub containment: bool,
    /// Degrade ladder past containment (only meaningful with
    /// `containment: true`). [`FailoverPolicy::God`] serves a quarantined
    /// tenant's re-queued waves on the Tetrad-style guaranteed-output-
    /// delivery backend ([`Backend::TetradGod`]) instead of inline-Trident
    /// forever, and after [`REHAB_AFTER`] consecutive clean failover waves
    /// rehabilitates the tenant back to keyed Trident serving (pool
    /// unquarantined, layer-key vector fill targets re-registered, refill
    /// restocks). The default [`FailoverPolicy::None`] keeps the
    /// pre-failover behaviour: quarantine is permanent for the run.
    pub failover: FailoverPolicy,
    /// Mid-serve fault injection (tests and CLI demos drive the
    /// containment path with it). `None` = honest run.
    pub fault: Option<FaultPlan>,
    /// Record the structured trace: run/wave/gate spans, scheduler and
    /// pool events, wave-boundary gauges. The hooks sit strictly after
    /// the metering arithmetic and never send, so metered bytes, msgs,
    /// rounds and virtual clocks are byte-for-byte identical with and
    /// without it (the observer-effect contract — tested).
    pub trace: bool,
    /// Per-tenant checkpoint restore: `resume[t] = Some(blobs)` resumes
    /// training tenant `t` from the four per-party [`Checkpoint`] blobs
    /// (party order `P0..P3` — each party decodes only its own). The
    /// restored weight shares are swapped in **before** any pool material
    /// is generated, the job's committed epochs are skipped at admission,
    /// and only the remaining epochs run. Empty (the default) = every
    /// training job starts at epoch 0.
    pub resume: Vec<Option<[Vec<u8>; 4]>>,
}

impl Default for MultiServeConfig {
    fn default() -> MultiServeConfig {
        MultiServeConfig {
            tenants: Vec::new(),
            mode: PoolMode::Keyed,
            low_water: 1,
            high_water: 2,
            age_every: 4,
            seed: 1234,
            containment: false,
            failover: FailoverPolicy::None,
            fault: None,
            trace: false,
            resume: Vec::new(),
        }
    }
}

/// What happens to a quarantined tenant's subsequent waves (see
/// [`MultiServeConfig::failover`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FailoverPolicy {
    /// Quarantine is permanent for the run: the tenant keeps serving over
    /// the secure inline Trident path (the pre-failover behaviour).
    #[default]
    None,
    /// Tetrad-style GOD failover: the quarantined tenant's waves deliver
    /// their outputs with guaranteed-output-delivery reconstruction
    /// ([`crate::proto::god_reconstruct_mat_to`]) — a single equivocating
    /// party can no longer force an abort at the output gate — and after
    /// [`REHAB_AFTER`] consecutive clean failover waves the tenant is
    /// rehabilitated back to keyed Trident serving.
    God,
}

/// Consecutive clean (committed) failover waves before a quarantined
/// tenant is rehabilitated back to keyed serving. Counted identically at
/// every party from committed-wave metadata, so the rehabilitation tick
/// is lockstep by construction.
pub const REHAB_AFTER: u64 = 2;

/// What a mid-serve injected fault does (see [`FaultPlan`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The faulty party corrupts the wire-mask skeleton of the victim
    /// tenant's front keyed matrix bundle right before its wave pops it —
    /// a malicious party serving tampered pool material mid-run.
    TamperMatLamX,
    /// Same, for the front nonlinear bundle's pre-exchanged `⟨γ_{r·v}⟩`
    /// (`relu: true` tenants).
    TamperReluGamma,
    /// The faulty party raises a verification abort **between** waves — a
    /// party-scoped failure outside any wave body. Containment must not
    /// catch it: the run fails closed.
    AbortOffWave,
}

/// One injected mid-serve fault: `party` acts maliciously against
/// `tenant`'s `wave`-th granted wave (0-based, counted per tenant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    pub party: PartyId,
    pub tenant: usize,
    pub wave: usize,
    /// Which gate position's bundle the tamper hits: the 0-based layer
    /// index into the tenant's per-layer key vector (always `0` for
    /// single-layer tenants). Irrelevant for [`FaultKind::AbortOffWave`].
    pub layer: u32,
    pub kind: FaultKind,
    /// Repeat period in per-tenant granted waves: `Some(e)` re-arms the
    /// fault every `e` grants after `wave` (grants `wave`, `wave + e`,
    /// `wave + 2e`, …) — the re-tamper-after-rehabilitation schedule. The
    /// tamper hooks return no bundle while the victim's shards are
    /// quarantined/drained, so a repeating tamper is naturally inert
    /// during failover and bites again only once rehabilitation has
    /// restocked the pool. `None` = fire once (the original behaviour).
    pub every: Option<u64>,
}

/// Per-tenant quarantine record of a contained abort. Every field is
/// derived from public wave metadata agreed over the four-party barrier,
/// so all four parties produce identical records (asserted at
/// aggregation).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QuarantineStats {
    /// Quarantined tenant index.
    pub tenant: usize,
    /// Logical tick of the containment decision.
    pub at_tick: u64,
    /// The poisoned wave's queries re-admitted with their original
    /// arrival ticks (served later over the secure inline path).
    pub requeued: usize,
    /// The poisoned wave's queries past their deadline at re-admission —
    /// swept as expired on the next tick, never served.
    pub lost: usize,
    /// Keyed matrix / nonlinear bundles drained from the poisoned shards.
    pub drained_mat: usize,
    pub drained_relu: usize,
    /// Why (public): the barrier statuses that produced the decision.
    pub why: String,
}

/// Which way a failover-ladder transition went (see [`TransitionStats`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransitionKind {
    /// The quarantined tenant degraded to the GOD failover backend.
    Failover,
    /// The tenant was rehabilitated back to keyed Trident serving.
    Rehab,
}

/// One failover-ladder transition of a tenant. Every field derives from
/// public lockstep metadata (the barrier-agreed quarantine decision and
/// the committed-wave count), so all four parties produce identical
/// records — asserted at aggregation, and stamped as `tenant.failover` /
/// `tenant.rehab` trace events with lockstep-identical skeletons.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TransitionStats {
    pub tenant: usize,
    /// Logical tick of the transition.
    pub at_tick: u64,
    /// The lockstep wave sequence number that triggered it (the failed
    /// wave for [`TransitionKind::Failover`], the last clean failover
    /// wave for [`TransitionKind::Rehab`]).
    pub wave: u64,
    pub kind: TransitionKind,
}

/// Deterministic query stream for one tenant (at the data owner).
pub fn tenant_query_stream(spec: &TenantSpec) -> Vec<F64Mat> {
    let mut rng = Rng::seeded(spec.seed ^ TQ_SEED);
    (0..spec.queries)
        .map(|_| {
            let mut x = F64Mat::zeros(spec.rows_per_query, spec.d);
            for r in 0..spec.rows_per_query {
                for c in 0..spec.d {
                    x.set(r, c, rng.normal());
                }
            }
            x
        })
        .collect()
}

/// Cleartext reference per tenant: one `Vec<f64>` per query, in query-id
/// order (test oracle). Each entry is the query's `rows_per_query ×
/// out_cols` output block flattened row-major — for legacy single-layer
/// tenants (`out_cols == 1`) that degenerates to the familiar vector of
/// row predictions. Deep tenants run the whole resident network: every
/// hidden layer ReLU-activated, the head linear.
pub fn cleartext_tenant_predictions(spec: &TenantSpec) -> Vec<Vec<f64>> {
    let ws = tenant_layer_weights(spec);
    tenant_query_stream(spec)
        .iter()
        .map(|x| {
            let mut a = x.clone();
            for (l, w) in ws.iter().enumerate() {
                a = a.matmul(w);
                if spec.layer_relu(l) {
                    for v in a.data.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
            }
            a.data
        })
        .collect()
}

/// Deterministic fixed training batch of a training tenant (at the data
/// owner): `batch × d` normal features plus `batch × out_cols` targets —
/// `{0, 1}` labels for logistic regression, small normal values otherwise.
/// The cleartext GD oracle of the equivalence suite regenerates exactly
/// this batch.
pub fn tenant_train_batch(spec: &TenantSpec) -> (F64Mat, F64Mat) {
    let (kind, _, batch, _, _) = spec.workload.training().expect("training tenant");
    let mut rng = Rng::seeded(spec.seed ^ TT_SEED);
    let mut x = F64Mat::zeros(batch, spec.d);
    for v in x.data.iter_mut() {
        *v = rng.normal() * 0.5;
    }
    let mut y = F64Mat::zeros(batch, spec.out_cols());
    for v in y.data.iter_mut() {
        *v = match kind {
            TrainKind::LogReg => {
                if rng.normal() > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            TrainKind::LinReg | TrainKind::Nn => rng.normal() * 0.5,
        };
    }
    (x, y)
}

/// Per-party live state of one scheduled training job.
struct TrainJob {
    /// The job's fixed batch, shared once by the data owner at admission.
    x: MMat<Z64>,
    y: MMat<Z64>,
    /// Committed epochs so far = the next epoch to run (pre-loaded from a
    /// restored checkpoint on resume).
    next_epoch: u64,
}

/// Per-party output of one multi-tenant run (internal).
struct MultiPartyOut {
    /// Tenant served per wave, wave order (identical at all parties).
    wave_tenant: Vec<usize>,
    /// Per-wave online virtual-time delta (this party).
    wave_lat: Vec<f64>,
    wave_rounds: Vec<u64>,
    /// Offline messages/bytes *this party* sent inside the wave window.
    wave_offline_msgs: Vec<u64>,
    wave_offline_bytes: Vec<u64>,
    /// Per-wave offline messages inside the matrix-gate and ReLU
    /// sub-windows — attributes the silence claim per op.
    wave_offline_msgs_mat: Vec<u64>,
    wave_offline_msgs_relu: Vec<u64>,
    /// The same two meters resolved per layer (gate order; length = the
    /// wave tenant's depth) — attributes the silence claim per gate.
    wave_offline_msgs_mat_layers: Vec<Vec<u64>>,
    wave_offline_msgs_relu_layers: Vec<Vec<u64>>,
    /// Whether the wave drained a keyed bundle (vs inline fallback).
    wave_keyed_hit: Vec<bool>,
    /// Whether the wave was a trailing partial batch (fewer queries than
    /// the tenant's coalescing factor).
    wave_partial: Vec<bool>,
    /// `(query id, sojourn ticks)` per query of each wave.
    wave_sojourn: Vec<Vec<(usize, u64)>>,
    /// Contained aborts, decision order (identical at all parties).
    quarantines: Vec<QuarantineStats>,
    /// Failover/rehab transitions, decision order (identical at all
    /// parties — empty unless `failover` is on and a tenant degraded).
    transitions: Vec<TransitionStats>,
    /// Whether each committed wave ran on the GOD failover backend.
    wave_failover: Vec<bool>,
    /// Refill ticks / keyed bundles generated, per tenant.
    refill_ticks: Vec<usize>,
    refill_mat_items: Vec<usize>,
    /// Online messages sent inside refill ticks (must stay 0).
    tick_online_msgs: u64,
    /// Logical ticks the loop ran for.
    ticks: u64,
    /// Decoded predictions per tenant (`(query id, row values)`), at the
    /// data owner only.
    answers: Vec<Vec<(usize, Vec<f64>)>>,
    /// Committed training epochs per tenant (0 for inference tenants).
    train_epochs: Vec<u64>,
    /// The reconstructed final model of a finished training job, decoded
    /// per layer (every party holds it — 4-way identity is asserted at
    /// aggregation).
    train_final: Vec<Option<Vec<Vec<f64>>>>,
    /// This party's serialized checkpoints per tenant: `(epoch, blob)` in
    /// commit order.
    train_ckpts: Vec<Vec<(u64, Vec<u8>)>>,
    queue_stats: SchedQueueStats,
    pool_stats: Option<PoolStats>,
    pool_left_mat: Vec<usize>,
    pool_left_relu: Vec<usize>,
    /// Shutdown stock resolved per layer shard (empty in inline mode).
    pool_left_mat_layers: Vec<Vec<usize>>,
    pool_left_relu_layers: Vec<Vec<usize>>,
    /// This party's structured trace (empty when `cfg.trace` is off).
    trace: Vec<TraceEvent>,
}

impl MultiPartyOut {
    fn new(nt: usize) -> MultiPartyOut {
        MultiPartyOut {
            wave_tenant: Vec::new(),
            wave_lat: Vec::new(),
            wave_rounds: Vec::new(),
            wave_offline_msgs: Vec::new(),
            wave_offline_bytes: Vec::new(),
            wave_offline_msgs_mat: Vec::new(),
            wave_offline_msgs_relu: Vec::new(),
            wave_offline_msgs_mat_layers: Vec::new(),
            wave_offline_msgs_relu_layers: Vec::new(),
            wave_keyed_hit: Vec::new(),
            wave_partial: Vec::new(),
            wave_sojourn: Vec::new(),
            quarantines: Vec::new(),
            transitions: Vec::new(),
            wave_failover: Vec::new(),
            refill_ticks: vec![0; nt],
            refill_mat_items: vec![0; nt],
            tick_online_msgs: 0,
            ticks: 0,
            answers: vec![Vec::new(); nt],
            train_epochs: vec![0; nt],
            train_final: vec![None; nt],
            train_ckpts: vec![Vec::new(); nt],
            queue_stats: SchedQueueStats::default(),
            pool_stats: None,
            pool_left_mat: vec![0; nt],
            pool_left_relu: vec![0; nt],
            pool_left_mat_layers: vec![Vec::new(); nt],
            pool_left_relu_layers: vec![Vec::new(); nt],
            trace: Vec::new(),
        }
    }
}

/// Aggregated per-tenant serving measurements.
#[derive(Clone, Debug)]
pub struct TenantServeStats {
    pub name: String,
    /// Queries offered / accepted / shed by admission control / answered /
    /// dropped past deadline.
    pub submitted: usize,
    pub admitted: usize,
    pub rejected: usize,
    pub served: usize,
    pub expired: usize,
    /// Waves granted to this tenant, and how they sourced their offline
    /// material (keyed-pool hit vs deterministic inline fallback).
    pub waves: usize,
    pub keyed_waves: usize,
    pub inline_waves: usize,
    /// Trailing partial waves (fewer queries than the coalescing factor),
    /// and how many of them still hit the keyed pool (the registered
    /// partial-wave key — counted either way).
    pub partial_waves: usize,
    pub partial_keyed_waves: usize,
    /// Tick at which this tenant was quarantined by a contained abort
    /// (`None` = never), plus the poisoned wave's re-queued/lost split.
    pub quarantined_at: Option<u64>,
    pub requeued: usize,
    pub lost: usize,
    /// Committed waves this tenant served on the GOD failover backend
    /// (0 unless the run used [`FailoverPolicy::God`] and the tenant was
    /// quarantined).
    pub failover_waves: usize,
    /// Tick at which the tenant was rehabilitated back to keyed Trident
    /// serving (`None` = never; the LAST rehabilitation when a repeating
    /// fault drove several failover cycles).
    pub rehabilitated_at: Option<u64>,
    /// Per-query online wave latency percentiles (virtual seconds; every
    /// query in a wave experiences that wave's latency).
    pub p50_latency: f64,
    pub p99_latency: f64,
    /// Queueing delay in logical ticks (admission → service start).
    pub mean_sojourn_ticks: f64,
    pub max_sojourn_ticks: u64,
    /// Offline-phase messages any party sent inside this tenant's wave
    /// windows (0 for warm keyed pools).
    pub offline_msgs_in_waves: u64,
    /// The matrix-gate / ReLU split of `offline_msgs_in_waves` — the
    /// silence claim, attributable per op.
    pub offline_msgs_matmul: u64,
    pub offline_msgs_relu: u64,
    /// The same split resolved per layer in gate order (length = the
    /// tenant's depth; `[total]` for legacy single-layer tenants) — a warm
    /// deep tenant must read all-zeros at EVERY gate, not just in total.
    pub offline_msgs_matmul_layers: Vec<u64>,
    pub offline_msgs_relu_layers: Vec<u64>,
    pub refill_ticks: usize,
    pub refill_mat_items: usize,
    /// Keyed bundles left under this tenant's layer-0 key at shutdown.
    pub pool_left_mat: usize,
    /// Nonlinear bundles left under this tenant's layer-0 ReLU key at
    /// shutdown (always paired with `pool_left_mat` for ReLU layers).
    pub pool_left_relu: usize,
    /// Shutdown stock per layer shard in gate order (empty in inline
    /// mode) — layer-vector refills keep these equal across layers.
    pub pool_left_mat_layers: Vec<usize>,
    pub pool_left_relu_layers: Vec<usize>,
    /// Decoded predictions (`(query id, row values)`), query-id order, as
    /// seen by the data owner.
    pub answers: Vec<(usize, Vec<f64>)>,
    /// Committed training epochs (0 for inference tenants — a training
    /// tenant's `served` counts the same epochs at the queue level).
    pub epochs_committed: u64,
    /// The finished training job's reconstructed model, decoded per layer
    /// (row-major) — `None` for inference tenants and unfinished jobs.
    /// Identical at all four parties (asserted at aggregation).
    pub final_model: Option<Vec<Vec<f64>>>,
    /// Serialized checkpoints in commit order: `(next epoch, the four
    /// per-party blobs in party order)` — feed one entry back through
    /// [`MultiServeConfig::resume`] to resume the job mid-stream.
    pub checkpoints: Vec<(u64, [Vec<u8>; 4])>,
}

/// Aggregated measurements of a multi-tenant run.
#[derive(Clone, Debug)]
pub struct MultiServeStats {
    pub tenants: Vec<TenantServeStats>,
    /// Total waves served, and the tenant of each wave in order (the
    /// planner's grant sequence — share-split assertions read this).
    pub waves: usize,
    pub wave_tenants: Vec<usize>,
    /// Online round cost of each wave (independent of how many queries the
    /// wave coalesced — the single-query shape, per tenant model).
    pub wave_rounds: Vec<u64>,
    /// Offline messages sent by any party inside each wave window.
    pub wave_offline_msgs: Vec<u64>,
    /// Logical ticks the scheduler ran for.
    pub ticks: u64,
    pub online_rounds: u64,
    /// Summed per-wave online latency (max across parties per wave).
    pub online_latency: f64,
    pub offline_msgs_in_waves: u64,
    pub offline_bytes_in_waves: u64,
    /// The matrix-gate / ReLU split of `offline_msgs_in_waves`.
    pub offline_msgs_matmul: u64,
    pub offline_msgs_relu: u64,
    /// Online messages inside refill ticks, summed over parties (must be 0).
    pub refill_online_msgs: u64,
    /// Pops where aging lifted an older lower-priority query (queue stat).
    pub aged_promotions: u64,
    /// Contained aborts in decision order (empty for honest runs and for
    /// runs with containment off). Identical at all four parties.
    pub quarantines: Vec<QuarantineStats>,
    /// Failover/rehab transitions in decision order (empty unless the run
    /// used a failover policy and a tenant degraded). Identical at all
    /// four parties — asserted at aggregation.
    pub transitions: Vec<TransitionStats>,
    pub pool_stats: Option<PoolStats>,
    pub report: NetReport,
    /// Merged lockstep trace (msgs/bytes summed over parties, rounds and
    /// compute maxed — mirroring how the scalar meters aggregate). Empty
    /// when `cfg.trace` was off. Aggregation asserts all four parties
    /// emitted identical trace *skeletons* before merging.
    pub trace: Vec<TraceEvent>,
    /// Each party's full event stream (lockstep AND per-party detail
    /// events like `net.send`) — the JSONL exporter's input. Empty when
    /// tracing was off.
    pub party_traces: Vec<Vec<TraceEvent>>,
}

/// One row of the per-protocol flame-style breakdown: a tenant's gate
/// position and op with its committed-wave count, offline messages
/// (summed over parties) and online compute span — the paper's
/// Table-6-shaped offline/online split resolved per gate.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OpRollup {
    pub tenant: usize,
    pub gate: usize,
    pub op: &'static str,
    pub waves: u64,
    pub offline_msgs: u64,
    pub compute_ns: u64,
}

impl MultiServeStats {
    /// Per-tenant per-gate per-op rollup of the merged trace (the
    /// schema-5 bench rows and the `bench` flame table render this).
    /// Falls back to the per-layer offline meters when the run was not
    /// traced — same msgs totals, but no compute spans (0).
    pub fn op_rollup(&self) -> Vec<OpRollup> {
        use std::collections::BTreeMap;
        let mut acc: BTreeMap<(usize, usize, &'static str), (u64, u64, u64)> = BTreeMap::new();
        if self.trace.is_empty() {
            for (t, ts) in self.tenants.iter().enumerate() {
                for (g, &m) in ts.offline_msgs_matmul_layers.iter().enumerate() {
                    acc.insert((t, g, "matmul"), (ts.waves as u64, m, 0));
                }
                for (g, &m) in ts.offline_msgs_relu_layers.iter().enumerate() {
                    acc.insert((t, g, "relu"), (ts.waves as u64, m, 0));
                }
            }
        } else {
            for e in &self.trace {
                let op = match e.op {
                    "gate.matmul" => "matmul",
                    "gate.relu" => "relu",
                    _ => continue,
                };
                let (Some(t), Some(g)) = (e.tenant, e.gate) else { continue };
                let row = acc.entry((t as usize, g as usize, op)).or_insert((0, 0, 0));
                row.0 += 1;
                row.1 += e.payload.msgs;
                row.2 += e.payload.compute_ns;
            }
        }
        acc.into_iter()
            .map(|((tenant, gate, op), (waves, offline_msgs, compute_ns))| OpRollup {
                tenant,
                gate,
                op,
                waves,
                offline_msgs,
                compute_ns,
            })
            .collect()
    }
}

/// Nearest-rank percentile of an unsorted sample (`p` in `[0, 1]`): the
/// smallest sorted value with at least `p·n` samples at or below it, i.e.
/// rank `⌈p·n⌉` (1-based, clamped to `[1, n]` so `p = 0` reads the
/// minimum and `p = 1` the maximum).
fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let n = v.len();
    let rank = (p * n as f64).ceil() as usize;
    v[rank.clamp(1, n) - 1]
}

/// One metered refill tick for tenant `t`, with the keyed top-up capped at
/// `max_mat` bundles — the tenant's remaining full-wave demand (refill
/// traffic must be offline-phase only; the online-message window check
/// pins that down).
fn tick_tenant(
    ctx: &mut Ctx,
    reg: &ModelRegistry,
    out: &mut MultiPartyOut,
    t: usize,
    max_mat: usize,
) -> Result<(), Abort> {
    let w = Window::open(ctx.net);
    let o = reg.tick(ctx, t, max_mat)?;
    let d = w.diff(ctx.net);
    out.tick_online_msgs += d.msgs(Phase::Online);
    out.refill_ticks[t] += 1;
    out.refill_mat_items[t] += o.mat_items;
    // lockstep identity (the tick comes from the cursor); the payload is
    // this party's measured offline refill traffic
    ctx.net.trace_event_at(
        "refill.tick",
        true,
        Some(t as u32),
        None,
        None,
        Payload {
            msgs: d.msgs(Phase::Offline),
            bytes: d.bytes(Phase::Offline),
            compute_ns: d.compute_ns(Phase::Offline),
            value: o.mat_items as i64,
            ..Payload::default()
        },
    );
    Ok(())
}

/// What one wave body produced (answers at the data owner only) — kept
/// out of [`MultiPartyOut`] until the containment boundary commits the
/// wave, so a quarantined wave's output (including any opened values a
/// party computed before an honest peer aborted) is discarded whole.
struct WaveOut {
    answers: Vec<(usize, Vec<f64>)>,
    /// Offline messages this party sent inside each gate window's
    /// matrix-gate / activation sub-window (window order, length = the
    /// tenant's [`TenantSpec::gate_windows`]).
    om_mat: Vec<u64>,
    om_relu: Vec<u64>,
    /// The matching per-gate online compute spans (this party's measured
    /// ns inside each sub-window) — the `gate.*` trace event payloads.
    cn_mat: Vec<u64>,
    cn_relu: Vec<u64>,
    /// A training epoch's updated weight shares — held here, NOT yet in
    /// the registry, so the containment boundary can discard an aborted
    /// epoch whole. `None` for inference waves.
    new_weights: Option<Vec<MMat<Z64>>>,
}

/// One wave's protocol body: stack the batch, then the tenant's whole
/// resident network — `Π_MatMulTr` per layer (keyed bundle vector on a
/// hit, deterministic inline fallback on a miss), hidden-layer batched
/// ReLU, verified reconstruction towards the data owner. Isolated so the
/// containment wrapper can classify and discard a failed wave.
///
/// Keyed sourcing is **all-or-nothing over the layer vector**: the wave
/// pops its per-layer bundles only if [`Pool::check_layer_vec`] sees every
/// gate's paired bundle in stock. A hole at ANY layer records one miss
/// and sends the entire wave down the inline path — a half-keyed wave
/// would split one query's trace across sourcing modes.
fn run_wave(
    ctx: &mut Ctx,
    reg: &ModelRegistry,
    spec: &TenantSpec,
    t: usize,
    rows: usize,
    batch: &[SchedQuery],
    keyed: bool,
    backend: Backend,
    wave_win: Window,
) -> Result<WaveOut, Abort> {
    let stacked: Option<F64Mat> = (ctx.id() == P2).then(|| {
        let mut m = F64Mat::zeros(rows, spec.d);
        let mut row = 0;
        for q in batch {
            let x = q.x.as_ref().expect("data owner holds query rows");
            for r in 0..q.rows {
                for c in 0..spec.d {
                    m.set(row, c, x.at(r, c));
                }
                row += 1;
            }
        }
        m
    });
    let depth = spec.depth();
    let keys = spec.layer_keys(rows);
    let use_keyed = keyed && ctx.pool_mut().is_some_and(|p| p.check_layer_vec(&keys));
    let model = reg.model(t);
    let (u, om_mat, om_relu, cn_mat, cn_relu) = if use_keyed {
        let weights: Vec<_> = model.layers.iter().map(|l| l.w.clone()).collect();
        let x_enc: Option<Matrix<Z64>> = stacked.as_ref().map(F64Mat::encode);
        let kf = forward_keyed(ctx, &weights, &keys, x_enc.as_ref())?;
        (kf.out, kf.om_mat, kf.om_relu, kf.cn_mat, kf.cn_relu)
    } else {
        let mut om_mat = Vec::with_capacity(depth);
        let mut om_relu = Vec::with_capacity(depth);
        let mut cn_mat = Vec::with_capacity(depth);
        let mut cn_relu = Vec::with_capacity(depth);
        let mut a = share_fixed_mat(ctx, P2, stacked.as_ref(), rows, spec.d)?;
        // the input share is attributed to layer 0's matrix window
        // (`wave_win` opened before the wave body started)
        let mut w = wave_win;
        for l in 0..depth {
            let u = matmul_tr(ctx, &a, &model.layers[l].w)?;
            let dm = w.diff(ctx.net);
            om_mat.push(dm.msgs(Phase::Offline));
            cn_mat.push(dm.compute_ns(Phase::Online));
            let wr = Window::open(ctx.net);
            a = if spec.layer_relu(l) {
                // flat path: SoA matrices end to end (share-vector
                // conversion lives inside the mat-level ReLU entry points)
                crate::ml::relu_mat(ctx, &u)?.0
            } else {
                u
            };
            let dr = wr.diff(ctx.net);
            om_relu.push(dr.msgs(Phase::Offline));
            cn_relu.push(dr.compute_ns(Phase::Online));
            w = Window::open(ctx.net);
        }
        (a, om_mat, om_relu, cn_mat, cn_relu)
    };
    // output delivery is the ONLY point where the tenant's backend
    // diverges: the masked evaluation above is identical across the
    // Trident / Tetrad variants (see `crate::proto::tetrad`)
    let opened = reconstruct_mat_to_backend(ctx, backend, &u, &[P2])?;
    let mut answers = Vec::new();
    if let Some(vals) = opened {
        let cols = spec.out_cols();
        let mut off = 0;
        for q in batch {
            let a: Vec<f64> = vals.data()[off..off + q.rows * cols]
                .iter()
                .map(|&v| FixedPoint::decode(v))
                .collect();
            answers.push((q.id, a));
            off += q.rows * cols;
        }
    }
    Ok(WaveOut { answers, om_mat, om_relu, cn_mat, cn_relu, new_weights: None })
}

/// One **training** wave: one epoch of the tenant's job — forward,
/// backward and weight update over its fixed batch (the gate taxonomy and
/// per-epoch regeneration rationale live in [`crate::sched::workload`]).
/// Keyed sourcing is all-or-nothing over the whole `3L−1` matrix-gate
/// vector; [`Pool::check_layer_vec_gates`] counts one miss **per cold
/// gate** so an unwarmed job's refill debt is visible, and any hole sends
/// the entire epoch down the deterministic inline path. The updated
/// weight shares ride back in [`WaveOut::new_weights`]: the registry swap,
/// checkpointing and pool regeneration all happen only after the
/// containment boundary commits the wave. The epoch's verification queue
/// is flushed before returning, so tampered material aborts inside the
/// wave body — classifiable by the four-party barrier like any inference
/// wave.
fn run_train_wave(
    ctx: &mut Ctx,
    reg: &ModelRegistry,
    spec: &TenantSpec,
    t: usize,
    job: &TrainJob,
    keyed: bool,
) -> Result<WaveOut, Abort> {
    let (kind, ..) = spec.workload.training().expect("training tenant");
    let keys = reg.model(t).train_keys();
    let gates = train_gate_keys(&keys);
    let use_keyed = keyed && ctx.pool_mut().is_some_and(|p| p.check_layer_vec_gates(&gates));
    let weights = reg.model(t).layer_weights();
    let head = match kind {
        // the piecewise sigmoid runs the generic msb/bit-injection
        // machinery inline (keyed sigmoid is a roadmap direction); the
        // offline-silence contract covers the linear-head trainers
        TrainKind::LogReg => HeadActivation::Sigmoid,
        TrainKind::LinReg | TrainKind::Nn => HeadActivation::Linear,
    };
    let out = train_step(
        ctx,
        &weights,
        head,
        spec.grad_shift(),
        use_keyed.then_some(keys.as_slice()),
        &job.x,
        &job.y,
    )?;
    ctx.flush_verify()?;
    Ok(WaveOut {
        answers: Vec::new(),
        om_mat: out.om_mat,
        om_relu: out.om_relu,
        cn_mat: out.cn_mat,
        cn_relu: out.cn_relu,
        new_weights: Some(out.weights),
    })
}

/// The per-party multi-tenant serving program.
fn serve_multi_party(ctx: &mut Ctx, cfg: &MultiServeConfig) -> Result<MultiPartyOut, Abort> {
    let nt = cfg.tenants.len();
    assert!(nt > 0, "serve_multi needs at least one tenant");
    assert!(
        cfg.mode != PoolMode::Scalar,
        "multi-tenant serving shards keyed material per tenant; use Inline or Keyed"
    );
    let keyed = cfg.mode == PoolMode::Keyed;
    if cfg.trace {
        ctx.net.trace().enable();
        ctx.net.trace_event("run.open", true, Payload::gauge(nt as i64));
    }

    // ---- model load: registry shares every tenant's weights (lockstep
    // tenant order), verified before any pool material is generated ----
    let mut reg = ModelRegistry::new();
    for spec in &cfg.tenants {
        reg.load(ctx, spec.clone(), cfg.low_water, cfg.high_water)?;
    }
    // training jobs: the data owner shares each job's fixed batch once at
    // admission (shapes are public schedule metadata, values private)
    let mut jobs: Vec<Option<TrainJob>> = Vec::with_capacity(nt);
    for spec in &cfg.tenants {
        if !spec.is_training() {
            jobs.push(None);
            continue;
        }
        let clear = (ctx.id() == P2).then(|| tenant_train_batch(spec));
        let x = share_fixed_mat(
            ctx,
            P2,
            clear.as_ref().map(|(x, _)| x),
            spec.rows_per_query,
            spec.d,
        )?;
        let y = share_fixed_mat(
            ctx,
            P2,
            clear.as_ref().map(|(_, y)| y),
            spec.rows_per_query,
            spec.out_cols(),
        )?;
        jobs.push(Some(TrainJob { x, y, next_epoch: 0 }));
    }
    ctx.flush_verify()?;
    // checkpoint restore: swap in the serialized weight shares (each party
    // decodes its own blob) BEFORE any pool material is generated, so the
    // warm-up fill embeds the restored λ; the committed epochs are skipped
    // at admission below (`next_q` starts at the restored epoch)
    for (t, r) in cfg.resume.iter().enumerate().take(nt) {
        let Some(blobs) = r else { continue };
        let spec = &cfg.tenants[t];
        assert!(spec.is_training(), "resume blob for non-training tenant {t}");
        let ck = Checkpoint::decode(&blobs[ctx.id().idx()])
            .unwrap_or_else(|e| panic!("tenant {t} checkpoint: {e}"));
        assert_eq!(ck.model, spec.model, "checkpoint names a different model");
        assert!(
            (ck.epoch as usize) <= spec.queries,
            "checkpoint epoch {} past the job's {} epochs",
            ck.epoch,
            spec.queries
        );
        reg.update_weights(t, ck.weights);
        jobs[t].as_mut().expect("training job").next_epoch = ck.epoch;
        ctx.net.trace_event_at(
            "ckpt.restore",
            true,
            Some(t as u32),
            None,
            None,
            Payload::gauge(ck.epoch as i64),
        );
    }

    let mut out = MultiPartyOut::new(nt);
    if keyed {
        ctx.attach_pool(Pool::new());
        // warm-up: stock every tenant's pool before the first wave. The
        // demand cap rounds UP (div_ceil): the trailing partial wave is
        // real demand too — its differently-shaped key is stocked once
        // right after, so full AND partial warm waves hit the pool.
        for t in 0..nt {
            let s = &cfg.tenants[t];
            if s.is_training() {
                // one whole-epoch gate vector against the (possibly
                // restored) weight shares — regenerated post-commit by the
                // wave path thereafter
                let o = reg.fill_train(ctx, t)?;
                out.refill_mat_items[t] += o.mat_items;
                continue;
            }
            tick_tenant(ctx, &reg, &mut out, t, s.queries.div_ceil(s.effective_coalesce()))?;
            let o = reg.warm_partial(ctx, t)?;
            out.refill_mat_items[t] += o.mat_items;
        }
    }

    // ---- admission edge: queue + per-tenant caps + arrival plan ----
    let mut queue = SchedQueue::new(nt, cfg.age_every);
    for (t, spec) in cfg.tenants.iter().enumerate() {
        if let Some(cap) = spec.inflight_cap {
            queue.set_cap(t, cap);
        }
        if spec.is_training() {
            // training never ages into the latency class: inference p99
            // under a saturating job stays EXACTLY what it is without one
            // (pinned by test). Starvation-freedom comes from the
            // epoch-granular waves draining whenever class 0 is idle.
            queue.set_unaged(t);
        }
    }
    let streams: Option<Vec<Vec<F64Mat>>> =
        (ctx.id() == P2).then(|| cfg.tenants.iter().map(tenant_query_stream).collect());
    let mut next_q = vec![0usize; nt];
    for (t, j) in jobs.iter().enumerate() {
        if let Some(j) = j {
            // a restored job re-admits only its remaining epochs
            next_q[t] = (j.next_epoch as usize).min(cfg.tenants[t].queries);
        }
    }

    // ---- scheduling loop, measured in isolation ----
    ctx.net.reset_clocks();
    let mut planner = WavePlanner::new(&reg.planner_weights());
    let mut now: u64 = 0;
    // lockstep wave sequence number (every granted wave, committed or
    // quarantined — the barrier's epoch index) and per-tenant grant
    // counters (the fault plan's trigger coordinate)
    let mut wave_seq: u64 = 0;
    let mut grants = vec![0usize; nt];
    // failover state machine (public lockstep metadata): under
    // `FailoverPolicy::God` a quarantined tenant's waves run on the GOD
    // backend; `clean_fo` counts its consecutive committed failover waves
    // towards rehabilitation at `REHAB_AFTER`
    let mut failover = vec![false; nt];
    let mut clean_fo = vec![0u64; nt];
    let max_class = cfg.tenants.iter().map(|s| s.class).max().unwrap_or(0);
    loop {
        ctx.net.trace().set_tick(now);
        // 1. arrivals due at this tick enter admission control
        for t in 0..nt {
            let spec = &cfg.tenants[t];
            while next_q[t] < spec.queries && spec.arrival_tick(next_q[t]) <= now {
                let id = next_q[t];
                let arrival = spec.arrival_tick(id);
                let admitted = queue.admit(SchedQuery {
                    tenant: t,
                    id,
                    rows: spec.rows_per_query,
                    class: spec.class,
                    arrival,
                    deadline: spec.deadline_ticks.map(|dl| arrival + dl),
                    x: streams.as_ref().map(|s| s[t][id].clone()),
                });
                if ctx.net.trace_on() {
                    let op = if admitted { "sched.admit" } else { "sched.reject" };
                    ctx.net
                        .trace_event_at(op, true, Some(t as u32), None, None, Payload::gauge(id as i64));
                }
                next_q[t] += 1;
            }
        }
        // 2. expiry sweep: past-deadline queries are counted, never served
        let expired = queue.expire(now);
        if expired > 0 {
            ctx.net.trace_event("sched.expire", true, Payload::gauge(expired as i64));
        }
        // 3. termination
        let arrivals_done = (0..nt).all(|t| next_q[t] >= cfg.tenants[t].queries);
        if queue.is_empty() && arrivals_done {
            break;
        }
        // 4. grant the wave: WRR across tenants eligible at the best class
        let elig = queue.eligible_mask(nt, now);
        let t = match planner.next(&elig) {
            Some(t) => t,
            None => {
                // backlog empty, arrivals still due later: idle tick
                now += 1;
                continue;
            }
        };
        let spec = &cfg.tenants[t];
        let batch = queue.pop_batch(t, spec.effective_coalesce(), now);
        debug_assert!(!batch.is_empty(), "an eligible tenant must yield a batch");

        // 5. run the tenant's wave inside the containment boundary:
        // meter snapshot → body → (containment) four-party outcome
        // barrier → commit, quarantine, or fail closed
        let rows: usize = batch.iter().map(|q| q.rows).sum();
        let this_wave = wave_seq;
        wave_seq += 1;
        ctx.net.trace().set_wave(t as u32, this_wave);
        ctx.net.trace_event("wave.start", true, Payload::gauge(batch.len() as i64));
        if spec.is_training() {
            // query id = epoch index (coalesce 1: one epoch per wave)
            ctx.net.trace_event("epoch.start", true, Payload::gauge(batch[0].id as i64));
        }
        let ww = Window::open(ctx.net);
        let h0 = ctx.pool.as_ref().map_or(0, |p| p.stats().mat_hits);
        let m0 = ctx.pool.as_ref().map_or(0, |p| p.stats().mat_misses);

        // mid-serve fault injection: the faulty party acts right before
        // the victim tenant's chosen wave pops its material
        if let Some(f) = cfg.fault {
            // one-shot at the planned grant, plus every `every`-th grant
            // after it when the plan repeats (re-tamper after rehab)
            let due = grants[t] == f.wave
                || matches!(f.every, Some(e) if e > 0
                    && grants[t] > f.wave
                    && (grants[t] - f.wave) as u64 % e == 0);
            if f.tenant == t && due && ctx.id() == f.party {
                match f.kind {
                    FaultKind::TamperMatLamX => {
                        let key = tenant_layer_key(spec, rows, f.layer as usize);
                        if let Some(item) = ctx.pool_mut().and_then(|p| p.mat_front_mut(&key)) {
                            item.tamper_lam_x();
                        }
                    }
                    FaultKind::TamperReluGamma => {
                        let rk = relu_key_for(&tenant_layer_key(spec, rows, f.layer as usize));
                        if let Some(item) = ctx.pool_mut().and_then(|p| p.relu_front_mut(&rk)) {
                            item.tamper_gamma();
                        }
                    }
                    FaultKind::AbortOffWave => {
                        // a party-scoped failure OUTSIDE any wave body:
                        // the containment wrapper never sees it, the run
                        // fails closed (peers die at their next recv or
                        // at the wave barrier)
                        return Err(ctx.net.abort(
                            "injected party-scoped fault between waves".into(),
                        ));
                    }
                }
            }
        }
        grants[t] += 1;

        // which 4PC backend delivers this wave's outputs: the tenant's
        // configured family, overridden to GOD while it is failed over
        let backend = if failover[t] { Backend::TetradGod } else { spec.backend };
        let res = if spec.is_training() {
            run_train_wave(ctx, &reg, spec, t, jobs[t].as_ref().expect("training job"), keyed)
        } else {
            run_wave(ctx, &reg, spec, t, rows, &batch, keyed, backend, ww)
        };
        // meter deltas captured before the barrier, so the Control-class
        // barrier round-trip cannot perturb the wave's numbers
        let d = ww.diff(ctx.net);
        let lat = d.clock(Phase::Online);
        let rounds_d = d.rounds(Phase::Online);
        let offm = d.msgs(Phase::Offline);
        let offb = d.bytes(Phase::Offline);
        let hit = ctx.pool.as_ref().map_or(0, |p| p.stats().mat_hits) > h0;
        let missed = ctx.pool.as_ref().map_or(0, |p| p.stats().mat_misses) > m0;

        let mut wave = if cfg.containment && keyed {
            // classify the local outcome: 0 = ok; 1 = failed in keyed
            // context (containable — a warm keyed wave draws no correlated
            // randomness, so every party's PRF streams are still in sync);
            // 2 = failed in inline context (the miss counter advanced →
            // inline generation was drawing correlated PRF streams when
            // the wave died; an interrupted draw cannot be re-synced)
            let status: u8 = match &res {
                Ok(_) => 0,
                Err(_) if missed => 2,
                Err(_) => 1,
            };
            if status != 0 {
                // unblock peers before waiting at the barrier (idempotent
                // if the failing protocol already flooded abort)
                ctx.net.signal_abort();
            }
            let statuses = ctx.net.wave_barrier(this_wave, status)?;
            let worst = *statuses.iter().max().expect("four statuses");
            if worst == 0 {
                res?
            } else if worst >= 2 {
                // some party was interrupted mid-inline-generation: PRF
                // stream sync is unprovable → escalate, fail closed
                return Err(Abort::TenantScoped {
                    model: spec.model,
                    tick: now,
                    why: format!(
                        "wave {this_wave} failed in inline context \
                         (statuses {statuses:?}) — not containable"
                    ),
                });
            } else {
                // the barrier agreed the blast radius is this tenant's
                // keyed material: quarantine it, re-admit the wave's
                // queries, keep serving everyone (lockstep decision — all
                // inputs are public wave metadata)
                ctx.reset_verify();
                let (dm, dr) =
                    ctx.pool_mut().map_or((0, 0), |p| p.quarantine_model(spec.model));
                reg.quarantine(t);
                let (mut requeued, mut lost) = (0usize, 0usize);
                for q in batch {
                    // service can restart at tick now+1 at the earliest;
                    // a query with deadline ≤ now is swept as expired on
                    // the next tick (the sweep does the stat/in-flight
                    // accounting, exercising the saturating decrement)
                    if matches!(q.deadline, Some(d) if d <= now) {
                        lost += 1;
                    } else {
                        requeued += 1;
                    }
                    queue.readmit(q);
                }
                out.quarantines.push(QuarantineStats {
                    tenant: t,
                    at_tick: now,
                    requeued,
                    lost,
                    drained_mat: dm,
                    drained_relu: dr,
                    why: format!(
                        "wave {this_wave} aborted in keyed context \
                         (statuses {statuses:?})"
                    ),
                });
                // a quarantined wave contributes NO gate events — the
                // trace rollup stays reconciled with committed meters
                ctx.net.trace_event("wave.quarantine", true, Payload::gauge(requeued as i64));
                if cfg.failover == FailoverPolicy::God {
                    // degrade, don't strand: the re-queued queries will be
                    // served on the GOD backend from the next grant on
                    failover[t] = true;
                    clean_fo[t] = 0;
                    out.transitions.push(TransitionStats {
                        tenant: t,
                        at_tick: now,
                        wave: this_wave,
                        kind: TransitionKind::Failover,
                    });
                    ctx.net
                        .trace_event("tenant.failover", true, Payload::gauge(this_wave as i64));
                }
                ctx.net.trace().clear_wave();
                now += 1;
                continue;
            }
        } else {
            // containment off (or inline mode): any abort is run-fatal
            res?
        };

        // training epoch commit: the wave survived the containment
        // boundary, so swap the updated weight shares into the registry,
        // advance the job, serialize a checkpoint on schedule, and — when
        // the job just finished — reconstruct the trained model at every
        // party (the job's deliverable; 4-way bit identity is asserted at
        // aggregation)
        if let Some(ws) = wave.new_weights.take() {
            let epoch = batch[0].id as u64;
            reg.update_weights(t, ws);
            let job = jobs[t].as_mut().expect("training job");
            job.next_epoch = epoch + 1;
            out.train_epochs[t] += 1;
            ctx.net.trace_event("epoch.commit", true, Payload::gauge(epoch as i64));
            let (_, epochs, _, ckpt_every, _) =
                spec.workload.training().expect("training tenant");
            if ckpt_every > 0 && job.next_epoch % ckpt_every as u64 == 0 {
                let blob = Checkpoint {
                    model: spec.model,
                    epoch: job.next_epoch,
                    weights: reg.model(t).layer_weights(),
                }
                .encode();
                ctx.net.trace_event("ckpt.save", true, Payload::gauge(blob.len() as i64));
                out.train_ckpts[t].push((job.next_epoch, blob));
            }
            if job.next_epoch as usize >= epochs {
                let mut fin = Vec::with_capacity(reg.model(t).layers.len());
                for w in reg.model(t).layer_weights() {
                    // the job's deliverable opens on the wave's effective
                    // backend: a failed-over job publishes its model with
                    // GOD delivery, abort-free at the output gate
                    let m = reconstruct_mat_backend(ctx, backend, &w)?;
                    fin.push(m.data().iter().map(|&v| FixedPoint::decode(v)).collect());
                }
                out.train_final[t] = Some(fin);
            }
        }

        // trace the committed wave: one span per gate (msgs from the same
        // sub-windows the meters use, so the rollup reconciles exactly),
        // then the wave-commit span
        if ctx.net.trace_on() {
            for l in 0..wave.om_mat.len() {
                ctx.net.trace().set_gate(l as u32);
                ctx.net.trace_event(
                    "gate.matmul",
                    true,
                    Payload {
                        msgs: wave.om_mat[l],
                        compute_ns: wave.cn_mat[l],
                        ..Payload::default()
                    },
                );
                ctx.net.trace_event(
                    "gate.relu",
                    true,
                    Payload {
                        msgs: wave.om_relu[l],
                        compute_ns: wave.cn_relu[l],
                        ..Payload::default()
                    },
                );
            }
            ctx.net.trace().clear_gate();
            ctx.net.trace_event(
                "wave.commit",
                true,
                Payload {
                    msgs: offm,
                    bytes: offb,
                    rounds: rounds_d,
                    compute_ns: d.compute_ns(Phase::Online),
                    value: batch.len() as i64,
                },
            );
        }

        out.wave_tenant.push(t);
        out.wave_lat.push(lat);
        out.wave_rounds.push(rounds_d);
        out.wave_offline_msgs.push(offm);
        out.wave_offline_bytes.push(offb);
        out.wave_offline_msgs_mat.push(wave.om_mat.iter().sum());
        out.wave_offline_msgs_relu.push(wave.om_relu.iter().sum());
        out.wave_offline_msgs_mat_layers.push(wave.om_mat);
        out.wave_offline_msgs_relu_layers.push(wave.om_relu);
        out.wave_keyed_hit.push(hit);
        out.wave_partial.push(batch.len() < spec.effective_coalesce());
        out.wave_failover.push(failover[t]);
        out.wave_sojourn
            .push(batch.iter().map(|q| (q.id, now - q.arrival)).collect());
        out.answers[t].extend(wave.answers);
        queue.complete(t, batch.len());

        // failover bookkeeping: a committed wave on the GOD backend counts
        // towards rehabilitation; at `REHAB_AFTER` consecutive clean waves
        // the tenant returns to keyed Trident serving — the pool shard is
        // unquarantined (its stock stays drained: quarantine never
        // resurrects material) and the registry re-arms the tenant's
        // layer-key fill targets, so the refill restocks it between waves
        if failover[t] {
            clean_fo[t] += 1;
            if clean_fo[t] >= REHAB_AFTER {
                failover[t] = false;
                clean_fo[t] = 0;
                if let Some(p) = ctx.pool_mut() {
                    p.unquarantine_model(spec.model);
                }
                reg.rehabilitate(t);
                out.transitions.push(TransitionStats {
                    tenant: t,
                    at_tick: now,
                    wave: this_wave,
                    kind: TransitionKind::Rehab,
                });
                ctx.net.trace_event("tenant.rehab", true, Payload::gauge(this_wave as i64));
            }
        }

        // wave-boundary gauge samples: queue depth per effective class,
        // in-flight per tenant, keyed pool stock per gate — all lockstep
        // functions of public scheduler/pool state
        if ctx.net.trace_on() {
            for class in 0..=max_class {
                let depth = queue.depth_class(class, now) as i64;
                ctx.net.trace_event_at(
                    "sched.depth",
                    true,
                    None,
                    None,
                    Some(class as u32),
                    Payload::gauge(depth),
                );
            }
            for tt in 0..nt {
                let inflight = queue.inflight(tt) as i64;
                ctx.net.trace_event_at(
                    "sched.inflight",
                    true,
                    Some(tt as u32),
                    None,
                    None,
                    Payload::gauge(inflight),
                );
            }
            let mut stock: Vec<(&'static str, u32, u32, i64)> = Vec::new();
            if let Some(pool) = ctx.pool.as_ref() {
                for tt in 0..nt {
                    for (l, layer) in reg.model(tt).layers.iter().enumerate() {
                        stock.push((
                            "pool.stock.mat",
                            tt as u32,
                            l as u32,
                            pool.len_mat(&layer.key) as i64,
                        ));
                        if let Some(rk) = layer.relu_key {
                            stock.push((
                                "pool.stock.relu",
                                tt as u32,
                                l as u32,
                                pool.len_relu(&rk) as i64,
                            ));
                        }
                    }
                }
            }
            for (op, tt, l, v) in stock {
                ctx.net.trace_event_at(op, true, Some(tt), None, Some(l), Payload::gauge(v));
            }
        }
        ctx.net.trace().clear_wave();

        // 6a. post-commit regeneration for the training tenant that just
        // committed an epoch: next epoch's bundles must embed the NEW
        // weight λ (material generated against the old weights would let
        // the evaluators difference wire masks and learn the weight deltas
        // — see `sched::workload`), so the wave path refills its own
        // tenant here, between waves, offline-phase, capped at the job's
        // remaining epochs
        if keyed && spec.is_training() && !reg.is_quarantined(t) {
            let remaining = (spec.queries - next_q[t]) + queue.pending_tenant(t);
            if remaining > 0 {
                let w = Window::open(ctx.net);
                let o = reg.fill_train(ctx, t)?;
                let d = w.diff(ctx.net);
                out.tick_online_msgs += d.msgs(Phase::Online);
                out.refill_ticks[t] += 1;
                out.refill_mat_items[t] += o.mat_items;
                ctx.net.trace_event_at(
                    "refill.train",
                    true,
                    Some(t as u32),
                    None,
                    None,
                    Payload {
                        msgs: d.msgs(Phase::Offline),
                        bytes: d.bytes(Phase::Offline),
                        compute_ns: d.compute_ns(Phase::Offline),
                        value: o.mat_items as i64,
                        ..Payload::default()
                    },
                );
            }
        }
        // 6. between waves: one refill tick for the most-depleted tenant
        // pool that can still consume a full wave; the tick's top-up is
        // capped at the tenant's remaining full-wave demand, so a late-run
        // refill can never stock a bundle the trailing partial wave (which
        // keys differently) would strand — only deadline expiry can still
        // orphan stocked material
        if keyed {
            let remaining_waves: Vec<usize> = (0..nt)
                .map(|tt| {
                    let s = &cfg.tenants[tt];
                    let remaining = (s.queries - next_q[tt]) + queue.pending_tenant(tt);
                    remaining / s.effective_coalesce()
                })
                .collect();
            let can_consume: Vec<bool> = remaining_waves.iter().map(|&w| w >= 1).collect();
            if let Some(tt) = reg.most_depleted(ctx, &can_consume) {
                tick_tenant(ctx, &reg, &mut out, tt, remaining_waves[tt])?;
            }
        }
        now += 1;
    }
    out.ticks = now;

    if let Some(pool) = ctx.detach_pool() {
        out.pool_stats = Some(pool.stats());
        for t in 0..nt {
            let m = reg.model(t);
            out.pool_left_mat[t] = pool.len_mat(&m.layers[0].key);
            out.pool_left_relu[t] =
                m.layers[0].relu_key.map_or(0, |rk| pool.len_relu(&rk));
            out.pool_left_mat_layers[t] =
                m.layers.iter().map(|l| pool.len_mat(&l.key)).collect();
            out.pool_left_relu_layers[t] = m
                .layers
                .iter()
                .map(|l| l.relu_key.map_or(0, |rk| pool.len_relu(&rk)))
                .collect();
        }
    }
    out.queue_stats = queue.stats().clone();
    if cfg.trace {
        ctx.net.trace().set_tick(now);
        ctx.net.trace_event("run.close", true, Payload::gauge(out.wave_tenant.len() as i64));
        out.trace = ctx.net.trace().take();
    }
    Ok(out)
}

/// Run the multi-tenant workload over `profile` and aggregate per-tenant
/// measurements, panicking on any abort (honest executions and contained
/// runs — a quarantine is NOT an abort at this level).
pub fn serve_multi(profile: NetProfile, cfg: MultiServeConfig) -> MultiServeStats {
    match serve_multi_checked(profile, cfg) {
        Ok(stats) => stats,
        Err(a) => panic!("serve_multi failed closed: {a}"),
    }
}

/// Like [`serve_multi`] but surfaces a run-fatal abort as `Err` instead
/// of panicking — the fail-closed contract of party-scoped aborts (and of
/// escalated tenant-scoped ones) is assertable with it. Prefers the most
/// specific abort across parties: a `Verify`/`TenantScoped` cause over
/// the `Signalled`/`Channel` echoes it provokes at the peers.
pub fn serve_multi_checked(
    profile: NetProfile,
    cfg: MultiServeConfig,
) -> Result<MultiServeStats, Abort> {
    let cfg2 = cfg.clone();
    let run = run_4pc(profile, cfg.seed, move |ctx| serve_multi_party(ctx, &cfg2));
    if run.outputs.iter().any(|o| o.is_err()) {
        let mut echo: Option<Abort> = None;
        for o in &run.outputs {
            if let Err(a) = o {
                match a {
                    Abort::Verify(_) | Abort::TenantScoped { .. } => return Err(a.clone()),
                    _ => {
                        echo.get_or_insert_with(|| a.clone());
                    }
                }
            }
        }
        return Err(echo.expect("some party erred"));
    }
    let outs = run.outputs.map(|o| o.expect("checked above"));
    Ok(aggregate(&cfg, outs, run.report))
}

/// Fold the four parties' outputs into [`MultiServeStats`].
fn aggregate(
    cfg: &MultiServeConfig,
    outs: [MultiPartyOut; 4],
    report: NetReport,
) -> MultiServeStats {
    let nt = cfg.tenants.len();
    // the containment decision is a function of public lockstep metadata:
    // all four parties must have produced identical quarantine records
    for o in &outs {
        assert_eq!(
            o.quarantines, outs[1].quarantines,
            "containment must be lockstep-deterministic across parties"
        );
        assert_eq!(
            o.transitions, outs[1].transitions,
            "all four parties must agree on every failover/rehab transition tick"
        );
        assert_eq!(
            o.wave_failover, outs[1].wave_failover,
            "the per-wave backend override is a lockstep decision"
        );
        assert_eq!(
            o.train_final, outs[1].train_final,
            "a finished job's reconstructed model must be identical at all four parties"
        );
        assert_eq!(o.train_epochs, outs[1].train_epochs, "epoch commits are lockstep");
    }
    // the trace recorder doubles as a correctness check: identity fields
    // are pure functions of public lockstep metadata, so all four parties
    // must have emitted identical trace skeletons
    let party_traces: Vec<Vec<TraceEvent>> = outs.iter().map(|o| o.trace.clone()).collect();
    if let Err(e) = obs::check_skeletons(&party_traces) {
        panic!("trace skeleton desync across parties: {e}");
    }
    let trace = obs::merge_lockstep(&party_traces);
    let waves = outs[1].wave_tenant.len();

    // per-wave latency is the max across parties; per-wave offline traffic
    // is summed over the parties' local sent counters (race-free)
    let wave_lat: Vec<f64> = (0..waves)
        .map(|i| outs.iter().map(|o| o.wave_lat[i]).fold(0.0f64, f64::max))
        .collect();
    let wave_off_msgs: Vec<u64> =
        (0..waves).map(|i| outs.iter().map(|o| o.wave_offline_msgs[i]).sum()).collect();
    let wave_off_bytes: Vec<u64> =
        (0..waves).map(|i| outs.iter().map(|o| o.wave_offline_bytes[i]).sum()).collect();
    let wave_off_mat: Vec<u64> =
        (0..waves).map(|i| outs.iter().map(|o| o.wave_offline_msgs_mat[i]).sum()).collect();
    let wave_off_relu: Vec<u64> =
        (0..waves).map(|i| outs.iter().map(|o| o.wave_offline_msgs_relu[i]).sum()).collect();
    let qs = &outs[1].queue_stats;

    let mut tenants = Vec::with_capacity(nt);
    for t in 0..nt {
        let spec = &cfg.tenants[t];
        let mut lats: Vec<f64> = Vec::new();
        let mut sojourns: Vec<u64> = Vec::new();
        let (mut waves_t, mut keyed_waves, mut inline_waves) = (0usize, 0usize, 0usize);
        let (mut partial_waves, mut partial_keyed_waves) = (0usize, 0usize);
        let mut failover_waves = 0usize;
        let (mut offm, mut offm_mat, mut offm_relu) = (0u64, 0u64, 0u64);
        // gate WINDOWS, not forward depth: a training tenant's wave emits
        // 3·depth − 1 per-gate meters (forward + grad + back windows)
        let windows = spec.gate_windows();
        let mut offm_mat_layers = vec![0u64; windows];
        let mut offm_relu_layers = vec![0u64; windows];
        for i in 0..waves {
            if outs[1].wave_tenant[i] != t {
                continue;
            }
            waves_t += 1;
            if outs[1].wave_keyed_hit[i] {
                keyed_waves += 1;
            } else {
                inline_waves += 1;
            }
            if outs[1].wave_partial[i] {
                partial_waves += 1;
                if outs[1].wave_keyed_hit[i] {
                    partial_keyed_waves += 1;
                }
            }
            if outs[1].wave_failover[i] {
                failover_waves += 1;
            }
            offm += wave_off_msgs[i];
            offm_mat += wave_off_mat[i];
            offm_relu += wave_off_relu[i];
            for o in &outs {
                for (l, v) in o.wave_offline_msgs_mat_layers[i].iter().enumerate() {
                    offm_mat_layers[l] += v;
                }
                for (l, v) in o.wave_offline_msgs_relu_layers[i].iter().enumerate() {
                    offm_relu_layers[l] += v;
                }
            }
            for &(_qid, so) in &outs[1].wave_sojourn[i] {
                sojourns.push(so);
                lats.push(wave_lat[i]);
            }
        }
        let quarantine = outs[1].quarantines.iter().find(|q| q.tenant == t);
        let mut answers = outs[2].answers[t].clone();
        answers.sort_by_key(|(id, _)| *id);
        // checkpoints: the schedule is lockstep (same epochs at every
        // party); the blobs are per-party views, zipped in party order
        for o in &outs {
            assert_eq!(
                o.train_ckpts[t].len(),
                outs[1].train_ckpts[t].len(),
                "checkpoint schedule must be lockstep"
            );
        }
        let checkpoints: Vec<(u64, [Vec<u8>; 4])> = (0..outs[1].train_ckpts[t].len())
            .map(|i| {
                let ep = outs[1].train_ckpts[t][i].0;
                let blobs = [0usize, 1, 2, 3].map(|p| {
                    let (e, b) = &outs[p].train_ckpts[t][i];
                    assert_eq!(*e, ep, "checkpoint epochs must agree across parties");
                    b.clone()
                });
                (ep, blobs)
            })
            .collect();
        tenants.push(TenantServeStats {
            name: spec.name.clone(),
            submitted: qs.submitted[t],
            admitted: qs.admitted[t],
            rejected: qs.rejected[t],
            served: qs.served[t],
            expired: qs.expired[t],
            waves: waves_t,
            keyed_waves,
            inline_waves,
            partial_waves,
            partial_keyed_waves,
            quarantined_at: quarantine.map(|q| q.at_tick),
            requeued: quarantine.map_or(0, |q| q.requeued),
            lost: quarantine.map_or(0, |q| q.lost),
            failover_waves,
            rehabilitated_at: outs[1]
                .transitions
                .iter()
                .filter(|tr| tr.tenant == t && tr.kind == TransitionKind::Rehab)
                .next_back()
                .map(|tr| tr.at_tick),
            p50_latency: percentile(&lats, 0.50),
            p99_latency: percentile(&lats, 0.99),
            mean_sojourn_ticks: if sojourns.is_empty() {
                0.0
            } else {
                sojourns.iter().sum::<u64>() as f64 / sojourns.len() as f64
            },
            max_sojourn_ticks: sojourns.iter().copied().max().unwrap_or(0),
            offline_msgs_in_waves: offm,
            offline_msgs_matmul: offm_mat,
            offline_msgs_relu: offm_relu,
            offline_msgs_matmul_layers: offm_mat_layers,
            offline_msgs_relu_layers: offm_relu_layers,
            refill_ticks: outs[1].refill_ticks[t],
            refill_mat_items: outs[1].refill_mat_items[t],
            pool_left_mat: outs[1].pool_left_mat[t],
            pool_left_relu: outs[1].pool_left_relu[t],
            pool_left_mat_layers: outs[1].pool_left_mat_layers[t].clone(),
            pool_left_relu_layers: outs[1].pool_left_relu_layers[t].clone(),
            answers,
            epochs_committed: outs[1].train_epochs[t],
            final_model: outs[1].train_final[t].clone(),
            checkpoints,
        });
    }

    let stats = MultiServeStats {
        tenants,
        waves,
        wave_tenants: outs[1].wave_tenant.clone(),
        wave_rounds: outs[1].wave_rounds.clone(),
        wave_offline_msgs: wave_off_msgs.clone(),
        ticks: outs[1].ticks,
        online_rounds: report.rounds[Phase::Online as usize],
        online_latency: wave_lat.iter().sum(),
        offline_msgs_in_waves: wave_off_msgs.iter().sum(),
        offline_bytes_in_waves: wave_off_bytes.iter().sum(),
        offline_msgs_matmul: wave_off_mat.iter().sum(),
        offline_msgs_relu: wave_off_relu.iter().sum(),
        refill_online_msgs: outs.iter().map(|o| o.tick_online_msgs).sum(),
        aged_promotions: qs.aged_promotions,
        quarantines: outs[1].quarantines.clone(),
        transitions: outs[1].transitions.clone(),
        pool_stats: outs[1].pool_stats,
        report,
        trace,
        party_traces,
    };
    // the trace-derived rollup must reconcile EXACTLY with the metered
    // per-op counters: gate events carry the same sub-window msgs the
    // meters sum, and both sides skip quarantined waves
    if !stats.trace.is_empty() {
        let (mut tm, mut tr) = (0u64, 0u64);
        for e in &stats.trace {
            match e.op {
                "gate.matmul" => tm += e.payload.msgs,
                "gate.relu" => tr += e.payload.msgs,
                _ => {}
            }
        }
        assert_eq!(
            tm, stats.offline_msgs_matmul,
            "trace matmul rollup must reconcile with offline_msgs_matmul"
        );
        assert_eq!(
            tr, stats.offline_msgs_relu,
            "trace relu rollup must reconcile with offline_msgs_relu"
        );
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, model: u64, queries: usize, coalesce: usize) -> TenantSpec {
        let mut s = TenantSpec::new(name, model, 12, queries, coalesce);
        s.rows_per_query = 2;
        s
    }

    fn two_tenant_cfg(mode: PoolMode) -> MultiServeConfig {
        MultiServeConfig {
            tenants: vec![spec("m1", 1, 4, 2), spec("m2", 2, 4, 2)],
            mode,
            low_water: 1,
            high_water: 2,
            age_every: 0,
            seed: 1400,
            ..MultiServeConfig::default()
        }
    }

    fn assert_answers_match_cleartext(stats: &MultiServeStats, cfg: &MultiServeConfig) {
        for (t, ts) in stats.tenants.iter().enumerate() {
            if cfg.tenants[t].is_training() {
                // training waves answer nothing; their deliverable is the
                // final model (checked by the training tests)
                continue;
            }
            let want = cleartext_tenant_predictions(&cfg.tenants[t]);
            assert_eq!(ts.answers.len(), ts.served, "one answer entry per served query");
            for (qid, rows) in &ts.answers {
                for (r, got) in rows.iter().enumerate() {
                    let w = want[*qid][r];
                    assert!(
                        (got - w).abs() < 0.01,
                        "tenant {t} query {qid} row {r}: got {got}, want {w}"
                    );
                }
            }
        }
    }

    #[test]
    fn two_tenant_answers_match_cleartext_keyed_and_inline() {
        for mode in [PoolMode::Keyed, PoolMode::Inline] {
            let cfg = two_tenant_cfg(mode);
            let stats = serve_multi(NetProfile::zero(), cfg.clone());
            for ts in &stats.tenants {
                assert_eq!(ts.served, 4, "all queries answered ({mode:?})");
                assert_eq!(ts.expired, 0);
                assert_eq!(ts.rejected, 0);
            }
            assert_answers_match_cleartext(&stats, &cfg);
        }
    }

    #[test]
    fn keyed_two_tenant_waves_hit_their_own_pools() {
        let cfg = two_tenant_cfg(PoolMode::Keyed);
        let stats = serve_multi(NetProfile::zero(), cfg);
        for ts in &stats.tenants {
            assert_eq!(ts.waves, 2, "4 queries / coalesce 2");
            assert_eq!(ts.keyed_waves, 2, "full waves must drain keyed bundles: {ts:?}");
            assert_eq!(ts.inline_waves, 0);
        }
        assert_eq!(stats.refill_online_msgs, 0, "refill traffic is offline-only");
    }

    #[test]
    fn higher_priority_tenant_is_served_first() {
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.tenants[0].class = 1;
        cfg.tenants[1].class = 0; // m2 outranks m1
        cfg.age_every = 0; // no aging: strict priority
        let stats = serve_multi(NetProfile::zero(), cfg);
        assert_eq!(
            &stats.wave_tenants[..2],
            &[1, 1],
            "class-0 tenant's waves must all precede class-1's: {:?}",
            stats.wave_tenants
        );
        assert_eq!(&stats.wave_tenants[2..], &[0, 0]);
    }

    #[test]
    fn deadline_expiry_counts_but_never_serves() {
        // one tenant, coalesce 1, 4 queries all at tick 0, deadline 1 tick:
        // waves at ticks 0 and 1 serve two queries; at tick 2 the remaining
        // two are past due and must be dropped, not served.
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.tenants.truncate(1);
        cfg.tenants[0] = {
            let mut s = spec("m1", 1, 4, 1);
            s.deadline_ticks = Some(1);
            s
        };
        let stats = serve_multi(NetProfile::zero(), cfg.clone());
        let ts = &stats.tenants[0];
        assert_eq!(ts.served, 2, "only in-deadline queries served: {ts:?}");
        assert_eq!(ts.expired, 2, "late queries counted as expired");
        assert_eq!(ts.answers.len(), 2);
        // EDF kept service in arrival order here, so the served ids are 0,1
        let ids: Vec<usize> = ts.answers.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![0, 1]);
        assert_answers_match_cleartext(&stats, &cfg);
    }

    #[test]
    fn admission_cap_sheds_burst_but_fits_staggered_arrivals() {
        // burst: 5 queries at tick 0 under a cap of 2 → 3 shed
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.tenants.truncate(1);
        cfg.tenants[0] = {
            let mut s = spec("m1", 1, 5, 1);
            s.inflight_cap = Some(2);
            s
        };
        let stats = serve_multi(NetProfile::zero(), cfg);
        let ts = &stats.tenants[0];
        assert_eq!(ts.admitted, 2);
        assert_eq!(ts.rejected, 3);
        assert_eq!(ts.served, 2);
        // staggered: one arrival per tick under the same cap → nothing shed
        let mut cfg2 = two_tenant_cfg(PoolMode::Keyed);
        cfg2.tenants.truncate(1);
        cfg2.tenants[0] = {
            let mut s = spec("m1", 1, 5, 1);
            s.inflight_cap = Some(2);
            s.arrive_per_tick = 1;
            s
        };
        let stats2 = serve_multi(NetProfile::zero(), cfg2);
        let ts2 = &stats2.tenants[0];
        assert_eq!(ts2.rejected, 0, "service keeps up with staggered arrivals: {ts2:?}");
        assert_eq!(ts2.served, 5);
    }

    #[test]
    fn weighted_round_robin_splits_waves_by_share() {
        let mut cfg = MultiServeConfig {
            tenants: vec![spec("heavy", 1, 12, 2), spec("light", 2, 12, 2)],
            mode: PoolMode::Keyed,
            low_water: 1,
            high_water: 2,
            age_every: 0,
            seed: 1401,
            ..MultiServeConfig::default()
        };
        cfg.tenants[0].weight = 2;
        cfg.tenants[1].weight = 1;
        let stats = serve_multi(NetProfile::zero(), cfg);
        // while both tenants are backlogged (first 9 waves), the 2:1 share
        // must hold to within one wave
        let heavy_prefix =
            stats.wave_tenants[..9].iter().filter(|&&t| t == 0).count() as f64;
        assert!(
            (heavy_prefix - 6.0).abs() <= 1.0,
            "2:1 split over 9 saturated waves, got {heavy_prefix} heavy waves: {:?}",
            stats.wave_tenants
        );
        // both drain completely in the end
        assert_eq!(stats.tenants[0].served, 12);
        assert_eq!(stats.tenants[1].served, 12);
    }

    #[test]
    fn relu_tenant_coexists_with_linear_tenant() {
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.tenants[1].relu = true;
        let stats = serve_multi(NetProfile::zero(), cfg.clone());
        assert_answers_match_cleartext(&stats, &cfg);
        let ps = stats.pool_stats.expect("pool attached");
        assert!(
            ps.relu_hits >= 1,
            "relu tenant must drain its keyed nonlinear bundles: {ps:?}"
        );
        assert_eq!(
            ps.bitext_hits, 0,
            "keyed tenants never touch the shared typed bitext queue: {ps:?}"
        );
        // the linear tenant consumed no nonlinear material
        assert_eq!(stats.tenants[0].offline_msgs_relu, 0);
        assert_eq!(stats.tenants[1].pool_left_relu, 0, "paired queues drain together");
    }

    #[test]
    fn percentile_is_nearest_rank_ceil() {
        let v = [10.0, 20.0, 30.0, 40.0];
        // nearest-rank: rank ⌈p·n⌉, 1-based. The old round((n−1)·p) rule
        // reported 30 for p50 of four samples; nearest-rank says 20.
        assert_eq!(percentile(&v, 0.50), 20.0);
        assert_eq!(percentile(&v, 0.25), 10.0);
        assert_eq!(percentile(&v, 0.26), 20.0, "⌈0.26·4⌉ = 2");
        assert_eq!(percentile(&v, 0.75), 30.0);
        assert_eq!(percentile(&v, 0.99), 40.0);
        assert_eq!(percentile(&v, 0.0), 10.0, "p=0 clamps to the minimum");
        assert_eq!(percentile(&v, 1.0), 40.0);
        // two samples: the median is the SMALLER one under nearest-rank
        assert_eq!(percentile(&[1.0, 2.0], 0.50), 1.0);
        // odd length and unsorted input
        assert_eq!(percentile(&[5.0, 1.0, 3.0], 0.50), 3.0);
        assert_eq!(percentile(&[5.0, 1.0, 3.0], 0.34), 3.0, "⌈0.34·3⌉ = 2");
        assert_eq!(percentile(&[], 0.50), 0.0, "empty sample reads 0");
    }

    #[test]
    fn trailing_partial_wave_hits_the_keyed_pool() {
        // 5 queries, coalesce 2 → two full waves + one trailing partial.
        // Before the partial-wave key was registered at load, the last
        // wave's differently-shaped CircuitKey always missed the pool and
        // fell back inline (offline traffic inside the wave window).
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.tenants.truncate(1);
        cfg.tenants[0] = spec("m1", 1, 5, 2);
        let stats = serve_multi(NetProfile::zero(), cfg.clone());
        let ts = &stats.tenants[0];
        assert_eq!(ts.served, 5);
        assert_eq!(ts.waves, 3, "5 queries / coalesce 2 → 2 full + 1 partial");
        assert_eq!(ts.partial_waves, 1, "{ts:?}");
        assert_eq!(ts.partial_keyed_waves, 1, "partial wave must hit its own key");
        assert_eq!(ts.keyed_waves, 3);
        assert_eq!(ts.inline_waves, 0);
        assert_eq!(
            ts.offline_msgs_in_waves, 0,
            "warm keyed waves, full AND partial, are offline-silent: {ts:?}"
        );
        assert_answers_match_cleartext(&stats, &cfg);
    }

    #[test]
    fn partial_wave_miss_is_counted_when_unregistered_shapes_pop() {
        // inline mode never touches the pool, so the partial wave simply
        // runs inline like every other wave — and still answers correctly
        let mut cfg = two_tenant_cfg(PoolMode::Inline);
        cfg.tenants.truncate(1);
        cfg.tenants[0] = spec("m1", 1, 5, 2);
        let stats = serve_multi(NetProfile::zero(), cfg.clone());
        let ts = &stats.tenants[0];
        assert_eq!(ts.partial_waves, 1);
        assert_eq!(ts.partial_keyed_waves, 0);
        assert_eq!(ts.inline_waves, 3);
        assert_answers_match_cleartext(&stats, &cfg);
    }

    #[test]
    fn containment_quarantines_poisoned_tenant_and_keeps_serving() {
        use crate::net::P1;
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.containment = true;
        cfg.fault = Some(FaultPlan {
            party: P1,
            tenant: 0,
            wave: 1,
            layer: 0,
            kind: FaultKind::TamperMatLamX,
            every: None,
        });
        let stats = serve_multi(NetProfile::zero(), cfg.clone());
        assert_eq!(stats.quarantines.len(), 1, "exactly one contained abort");
        let q = &stats.quarantines[0];
        assert_eq!(q.tenant, 0);
        assert_eq!(q.requeued, 2, "the poisoned wave's batch is re-admitted");
        assert_eq!(q.lost, 0, "no deadlines → nothing is lost");
        assert!(q.drained_mat > 0, "quarantine drains the poisoned shard: {q:?}");
        let ts = &stats.tenants[0];
        assert_eq!(ts.quarantined_at, Some(q.at_tick));
        assert_eq!(ts.served, 4, "re-queued queries are served after quarantine");
        assert!(
            ts.inline_waves >= 1,
            "the quarantined tenant finishes over the inline path: {ts:?}"
        );
        let other = &stats.tenants[1];
        assert_eq!(other.served, 4, "the innocent tenant is unaffected");
        assert_eq!(other.quarantined_at, None);
        // every surviving answer — innocent tenant AND the re-queued
        // queries of the quarantined one — matches the cleartext oracle
        assert_answers_match_cleartext(&stats, &cfg);
    }

    #[test]
    fn containment_off_tamper_fails_the_run_closed() {
        use crate::net::P1;
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.fault = Some(FaultPlan {
            party: P1,
            tenant: 0,
            wave: 1,
            layer: 0,
            kind: FaultKind::TamperMatLamX,
            every: None,
        });
        let err = serve_multi_checked(NetProfile::zero(), cfg)
            .expect_err("without containment any abort is run-fatal");
        assert!(
            matches!(err, Abort::Verify(_)),
            "the root cause is a verification abort: {err}"
        );
    }

    #[test]
    fn containment_never_catches_party_scoped_aborts() {
        use crate::net::P3;
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.containment = true;
        cfg.fault = Some(FaultPlan {
            party: P3,
            tenant: 1,
            wave: 0,
            layer: 0,
            kind: FaultKind::AbortOffWave,
            every: None,
        });
        let err = serve_multi_checked(NetProfile::zero(), cfg)
            .expect_err("a party-scoped abort outside a wave body fails closed");
        assert!(
            matches!(err, Abort::Verify(_)),
            "the faulty party's own abort cause wins over peer echoes: {err}"
        );
    }

    #[test]
    fn quarantine_with_deadlines_loses_past_due_queries_deterministically() {
        use crate::net::P1;
        // coalesce 2, deadline 1 tick: when the tamper kills wave 0, its
        // two queries are already at their service-start deadline — both
        // are re-admitted but swept as expired on the next tick (the
        // sweep's saturating in-flight decrement is exercised here)
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.tenants.truncate(1);
        cfg.tenants[0] = {
            let mut s = spec("m1", 1, 4, 2);
            s.deadline_ticks = Some(0);
            s
        };
        cfg.containment = true;
        cfg.fault = Some(FaultPlan {
            party: P1,
            tenant: 0,
            wave: 0,
            layer: 0,
            kind: FaultKind::TamperMatLamX,
            every: None,
        });
        let stats = serve_multi(NetProfile::zero(), cfg);
        let q = &stats.quarantines[0];
        assert_eq!(q.lost, 2, "deadline ≤ quarantine tick → lost: {q:?}");
        assert_eq!(q.requeued, 0);
        let ts = &stats.tenants[0];
        assert_eq!(ts.expired, 4, "lost queries surface as expired, never served");
        assert_eq!(ts.served, 0);
    }

    /// A resident 3-layer network (4-8-8-2, hidden ReLU, linear head).
    fn deep_spec(name: &str, model: u64, queries: usize, coalesce: usize) -> TenantSpec {
        let mut s = TenantSpec::new(name, model, 4, queries, coalesce);
        s.rows_per_query = 2;
        s.layers = vec![8, 8, 2];
        s
    }

    #[test]
    fn deep_tenant_warm_waves_are_offline_silent_at_every_gate() {
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.tenants[1] = deep_spec("deep", 2, 4, 2);
        let stats = serve_multi(NetProfile::zero(), cfg.clone());
        let ts = &stats.tenants[1];
        assert_eq!(ts.served, 4);
        assert_eq!(ts.keyed_waves, 2, "warm deep waves pop the whole layer vector: {ts:?}");
        assert_eq!(ts.inline_waves, 0);
        assert_eq!(ts.offline_msgs_in_waves, 0, "deep keyed waves are offline-silent");
        assert_eq!(ts.offline_msgs_matmul_layers, vec![0, 0, 0], "silent at every gate");
        assert_eq!(ts.offline_msgs_relu_layers, vec![0, 0, 0]);
        assert_eq!(ts.pool_left_mat_layers.len(), 3, "one shard per layer at shutdown");
        // answers carry the full rows × out_cols block per query
        assert_eq!(ts.answers[0].1.len(), 2 * 2);
        // the legacy single-layer tenant is unchanged next to the deep one
        assert_eq!(stats.tenants[0].served, 4);
        assert_eq!(stats.tenants[0].offline_msgs_matmul_layers, vec![0]);
        assert_answers_match_cleartext(&stats, &cfg);
    }

    #[test]
    fn deep_tenant_inline_mode_matches_cleartext() {
        let mut cfg = two_tenant_cfg(PoolMode::Inline);
        cfg.tenants[1] = deep_spec("deep", 2, 4, 2);
        let stats = serve_multi(NetProfile::zero(), cfg.clone());
        let ts = &stats.tenants[1];
        assert_eq!(ts.served, 4);
        assert_eq!(ts.inline_waves, 2);
        assert!(
            ts.offline_msgs_in_waves > 0,
            "inline deep waves pay offline traffic inside the wave window: {ts:?}"
        );
        assert_answers_match_cleartext(&stats, &cfg);
    }

    #[test]
    fn deep_tamper_at_inner_layer_fails_closed_without_containment() {
        use crate::net::P1;
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.tenants[0] = deep_spec("deep", 1, 4, 2);
        cfg.fault = Some(FaultPlan {
            party: P1,
            tenant: 0,
            wave: 0,
            layer: 1,
            kind: FaultKind::TamperMatLamX,
            every: None,
        });
        let err = serve_multi_checked(NetProfile::zero(), cfg)
            .expect_err("a tampered bundle at ANY gate position must abort the run");
        assert!(matches!(err, Abort::Verify(_)), "root cause is a verification abort: {err}");
    }

    #[test]
    fn deep_containment_quarantines_on_hidden_gate_relu_tamper_and_keeps_serving() {
        use crate::net::P1;
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.tenants[0] = deep_spec("deep", 1, 4, 2);
        cfg.containment = true;
        // tamper the hidden gate 1's nonlinear bundle (the head at gate 2
        // is linear and owns no ReLU shard)
        cfg.fault = Some(FaultPlan {
            party: P1,
            tenant: 0,
            wave: 1,
            layer: 1,
            kind: FaultKind::TamperReluGamma,
            every: None,
        });
        let stats = serve_multi(NetProfile::zero(), cfg.clone());
        assert_eq!(stats.quarantines.len(), 1, "exactly one contained abort");
        let q = &stats.quarantines[0];
        assert_eq!(q.tenant, 0);
        assert_eq!(q.requeued, 2);
        // the drain covers ALL of the tenant's layer shards atomically:
        // whatever vector stock remains, it leaves as whole per-layer
        // vectors — 3 matrix shards and 2 hidden-ReLU shards per vector
        assert_eq!(q.drained_mat % 3, 0, "mat shards drain in whole layer-vector units: {q:?}");
        assert_eq!(
            q.drained_relu * 3,
            q.drained_mat * 2,
            "2 hidden ReLU shards drain per 3 matrix shards: {q:?}"
        );
        let ts = &stats.tenants[0];
        assert_eq!(ts.served, 4, "re-queued queries finish over the inline path");
        assert!(ts.inline_waves >= 1);
        assert_eq!(stats.tenants[1].served, 4, "the innocent tenant is unaffected");
        assert_answers_match_cleartext(&stats, &cfg);
    }

    #[test]
    fn training_job_warm_epochs_are_offline_silent_at_every_gate() {
        // a 4-6-2 NN training job (3 epochs, batch 8) shares the cluster
        // with a latency-sensitive inference tenant; every warm keyed epoch
        // must pop its whole forward+grad+back gate vector and send ZERO
        // offline-phase messages inside the wave window
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.tenants[1] =
            TenantSpec::training("job", 9, 4, vec![6, 2], TrainKind::Nn, 3, 8, 0, 5);
        let stats = serve_multi(NetProfile::zero(), cfg.clone());
        let ts = &stats.tenants[1];
        assert_eq!(ts.served, 3, "one wave per epoch: {ts:?}");
        assert_eq!(ts.keyed_waves, 3, "warm epochs draw from the per-epoch pools");
        assert_eq!(ts.inline_waves, 0);
        assert_eq!(ts.epochs_committed, 3);
        assert_eq!(
            ts.offline_msgs_in_waves, 0,
            "warm keyed training epochs are offline-silent: {ts:?}"
        );
        // 3·depth−1 = 5 gate windows: fwd0, fwd1, grad1, back1, grad0 —
        // silence must hold at EVERY gate, forward and backward
        assert_eq!(ts.offline_msgs_matmul_layers, vec![0; 5], "silent at every gate");
        assert_eq!(ts.offline_msgs_relu_layers, vec![0; 5]);
        assert!(ts.final_model.is_some(), "finished job publishes its model");
        assert!(ts.checkpoints.is_empty(), "checkpoint_every = 0 → none taken");
        // the inference tenant is fully served next to the training job
        let inf = &stats.tenants[0];
        assert_eq!(inf.served, 4);
        assert_eq!(inf.epochs_committed, 0);
        assert_answers_match_cleartext(&stats, &cfg);
    }

    #[test]
    fn saturating_training_job_does_not_move_inference_latency() {
        // baseline: inference tenants alone (aging on, as in production)
        let mut base = two_tenant_cfg(PoolMode::Keyed);
        base.age_every = 4;
        let alone = serve_multi(NetProfile::zero(), base.clone());
        // same cluster plus a saturating class-1 LinReg training job: the
        // job is exempt from aging, so class-0 inference waves win every
        // tick they have work — the inference latency distribution must be
        // EXACTLY unchanged, not merely close
        let mut mixed_cfg = base.clone();
        mixed_cfg.tenants.push(TenantSpec::training(
            "job",
            9,
            4,
            vec![],
            TrainKind::LinReg,
            6,
            8,
            0,
            4,
        ));
        let mixed = serve_multi(NetProfile::zero(), mixed_cfg);
        for t in 0..2 {
            let (a, b) = (&alone.tenants[t], &mixed.tenants[t]);
            assert_eq!(b.served, a.served, "tenant {t} serves the same queries");
            assert_eq!(b.p50_latency, a.p50_latency, "tenant {t} p50 moved: {b:?}");
            assert_eq!(
                b.p99_latency, a.p99_latency,
                "tenant {t} p99 must not move under concurrent training: {b:?}"
            );
            assert_eq!(b.mean_sojourn_ticks, a.mean_sojourn_ticks, "tenant {t} sojourn");
            assert_eq!(b.max_sojourn_ticks, a.max_sojourn_ticks, "tenant {t} sojourn");
        }
        // and the training job still makes full progress in the gaps
        let job = &mixed.tenants[2];
        assert_eq!(job.epochs_committed, 6, "background job completes: {job:?}");
        assert!(job.final_model.is_some());
    }

    #[test]
    fn god_failover_serves_every_query_and_rehabilitates() {
        use crate::net::P1;
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.tenants[0] = spec("m1", 1, 12, 2);
        cfg.containment = true;
        cfg.failover = FailoverPolicy::God;
        cfg.fault = Some(FaultPlan {
            party: P1,
            tenant: 0,
            wave: 1,
            layer: 0,
            kind: FaultKind::TamperMatLamX,
            every: None,
        });
        let stats = serve_multi(NetProfile::zero(), cfg.clone());
        assert_eq!(stats.quarantines.len(), 1, "exactly one contained abort");
        let ts = &stats.tenants[0];
        assert_eq!(ts.served, 12, "GOD failover completes every admitted query: {ts:?}");
        assert_eq!(ts.lost, 0);
        assert_eq!(ts.expired, 0);
        assert_eq!(
            ts.failover_waves, REHAB_AFTER as usize,
            "exactly the clean waves the rehab rule demands run on GOD: {ts:?}"
        );
        assert!(ts.rehabilitated_at.is_some(), "{ts:?}");
        let kinds: Vec<TransitionKind> = stats.transitions.iter().map(|tr| tr.kind).collect();
        assert_eq!(kinds, vec![TransitionKind::Failover, TransitionKind::Rehab]);
        assert!(stats.transitions.iter().all(|tr| tr.tenant == 0));
        // rehabilitation restocks the shard: later waves are keyed again
        assert!(
            ts.keyed_waves >= 2,
            "post-rehab waves must return to the keyed pool: {ts:?}"
        );
        let other = &stats.tenants[1];
        assert_eq!(other.served, 4, "the innocent tenant is unaffected");
        assert_eq!(other.failover_waves, 0);
        assert_answers_match_cleartext(&stats, &cfg);
    }

    #[test]
    fn repeating_fault_drives_a_stable_failover_rehab_cycle() {
        use crate::net::P1;
        // the fault re-arms every 8 grants: it bites at grant 1, is inert
        // while the shard is drained (failover), and bites again at grant
        // 9 — only possible because rehabilitation restocked the pool
        let mut cfg = two_tenant_cfg(PoolMode::Keyed);
        cfg.tenants.truncate(1);
        cfg.tenants[0] = spec("m1", 1, 20, 2);
        cfg.containment = true;
        cfg.failover = FailoverPolicy::God;
        cfg.fault = Some(FaultPlan {
            party: P1,
            tenant: 0,
            wave: 1,
            layer: 0,
            kind: FaultKind::TamperMatLamX,
            every: Some(8),
        });
        let stats = serve_multi(NetProfile::zero(), cfg.clone());
        assert_eq!(
            stats.quarantines.len(),
            2,
            "the repeating fault quarantines once per cycle: {:?}",
            stats.quarantines
        );
        let ts = &stats.tenants[0];
        assert_eq!(ts.served, 20, "both cycles complete every query: {ts:?}");
        assert_eq!(ts.expired, 0);
        let kinds: Vec<TransitionKind> = stats.transitions.iter().map(|tr| tr.kind).collect();
        assert_eq!(
            kinds,
            vec![
                TransitionKind::Failover,
                TransitionKind::Rehab,
                TransitionKind::Failover,
                TransitionKind::Rehab
            ],
            "{:?}",
            stats.transitions
        );
        assert_eq!(ts.failover_waves, 2 * REHAB_AFTER as usize);
        assert_answers_match_cleartext(&stats, &cfg);
        // the cycle is stable: an identical re-run reproduces the exact
        // quarantine and transition schedule
        let again = serve_multi(NetProfile::zero(), cfg);
        assert_eq!(again.quarantines, stats.quarantines);
        assert_eq!(again.transitions, stats.transitions);
    }

    #[test]
    fn checkpoint_restore_resumes_to_identical_final_model() {
        let job = || TenantSpec::training("job", 9, 4, vec![6, 2], TrainKind::Nn, 4, 8, 2, 5);
        let full_cfg = MultiServeConfig {
            tenants: vec![job()],
            mode: PoolMode::Keyed,
            low_water: 1,
            high_water: 2,
            age_every: 0,
            seed: 1500,
            ..MultiServeConfig::default()
        };
        let full = serve_multi(NetProfile::zero(), full_cfg.clone());
        let ts = &full.tenants[0];
        assert_eq!(ts.epochs_committed, 4);
        let final_full = ts.final_model.clone().expect("full run finishes");
        // checkpoint_every = 2 over 4 epochs → blobs after epochs 2 and 4
        assert_eq!(
            ts.checkpoints.iter().map(|(e, _)| *e).collect::<Vec<u64>>(),
            vec![2, 4],
            "{ts:?}"
        );
        // restore from the mid-job checkpoint: the resumed run re-admits
        // only the remaining epochs and lands on the full run's model (to
        // fixed-point tolerance — probabilistic truncation re-rounds under
        // the resumed run's fresh PRF randomness; the four parties of the
        // resumed run agree EXACTLY, asserted inside aggregation)
        let (ck_epoch, blobs) = ts.checkpoints[0].clone();
        assert_eq!(ck_epoch, 2);
        let mut resume_cfg = full_cfg;
        resume_cfg.resume = vec![Some(blobs)];
        let resumed = serve_multi(NetProfile::zero(), resume_cfg);
        let rs = &resumed.tenants[0];
        assert_eq!(rs.epochs_committed, 2, "only the remaining epochs run: {rs:?}");
        assert_eq!(rs.served, 2);
        let final_resumed = rs.final_model.clone().expect("resumed run finishes");
        assert_eq!(final_resumed.len(), final_full.len());
        for (l, (a, b)) in final_full.iter().zip(final_resumed.iter()).enumerate() {
            assert_eq!(a.len(), b.len());
            for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                assert!(
                    (x - y).abs() < 0.01,
                    "layer {l} weight {i}: full {x} vs resumed {y}"
                );
            }
        }
    }
}
