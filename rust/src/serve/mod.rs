//! Batched online serving engine — the MLaaS loop of §I run the way the
//! paper's offline/online split intends: all input-independent work
//! pre-generated into the [`crate::pool`], concurrent inference queries
//! coalesced into cross-request batches so a whole wave of traffic costs
//! one protocol round-trip, and per-query amortized cost reported through
//! the existing meter.
//!
//! ## Pool modes
//!
//! * [`PoolMode::Inline`] — the seed's path: every wave runs its own
//!   offline phase live (γ-exchange + truncation-pair generation).
//! * [`PoolMode::Scalar`] — PR 1's typed scalar pools: truncation pairs /
//!   λ / bitext masks pre-generated, but `matmul_offline`'s γ-exchange
//!   still runs live per wave, so the per-request offline phase is cheap
//!   but **not** message-free.
//! * [`PoolMode::Keyed`] — circuit-position-keyed pooling
//!   ([`crate::pool::mat`] + [`crate::pool::relu`]): at model load the
//!   engine registers one [`CircuitKey`] per resident gate — the matrix
//!   position and, for a ReLU pipeline, its **paired nonlinear position**.
//!   Each wave then drains one keyed matrix bundle (pre-drawn input wire
//!   mask, pre-exchanged `⟨Γ⟩`, truncation pairs) and, when the pipeline
//!   ends in a ReLU, one paired `ReluCorr` bundle (bit-extraction masks,
//!   pre-exchanged `⟨γ_{r·v}⟩`, pre-checked `Π_BitInj` material) — so the
//!   **whole wave performs zero offline-phase messages**, the framework's
//!   core invariant, pinned down per op by the per-party sent-traffic
//!   counters (`offline_msgs_matmul` / `offline_msgs_relu`).
//!
//! ## Background refill
//!
//! Instead of one up-front fill sized to the workload, the engine drives a
//! [`Refill`] producer: targets registered at load with `{low, high}`
//! water marks, topped up cooperatively **between** waves
//! ([`crate::pool::refill`] documents the state machine and why the
//! lockstep decision is deterministic). Refill traffic is metered
//! `Phase::Offline` only. The single-tenant engine registers one
//! full-wave key, so its trailing partial wave (fewer rows than the
//! registered key) falls back to the inline path deterministically; the
//! multi-tenant registry additionally registers the partial-wave shape at
//! load and warms it once, keeping full AND partial waves offline-silent.
//!
//! Pipeline per coalesced batch: stack up to `coalesce` pending queries
//! into one matrix; share it (under the pooled wire mask in keyed mode);
//! one `Π_MatMulTr` against the resident model (optionally + batched
//! ReLU); reconstruct towards the data owner with the batched verification
//! digests flushed — every response is verified before release. Rounds per
//! batch are independent of how many queries were coalesced.
//!
//! ## Multi-tenant serving
//!
//! [`multi`] lifts this engine to N resident models behind one cluster:
//! the [`crate::sched`] subsystem (model registry with per-tenant keyed
//! pools, deadline/priority queue, weighted-round-robin wave planner with
//! most-depleted refill steering) decides whose wave runs next, and each
//! wave executes the per-model pipeline above — generalized to **deep
//! resident networks**: a tenant registered with hidden layers carries one
//! keyed bundle pair per gate (`CircuitKey::layer` = position), a warm
//! wave pops the whole per-layer vector all-or-nothing and runs
//! share → L×(keyed matmul → hidden ReLU) → reconstruct offline-silent at
//! every gate ([`crate::ml::nn::forward_keyed`]). With containment
//! enabled, a keyed wave that aborts is scoped over a four-party outcome
//! barrier: the poisoned tenant is quarantined — all of its layer shards
//! drained as whole vectors — and everyone else keeps being served (see
//! [`multi`] and the abort-scoping contract in [`crate::net`]). A run
//! with `--failover god` extends that ladder one rung further: the
//! quarantined tenant's re-queued waves degrade to the Tetrad-style
//! guaranteed-output-delivery backend ([`crate::proto::tetrad`]) instead
//! of serving inline forever, and after consecutive clean failover waves
//! the tenant is rehabilitated back to keyed Trident serving
//! ([`multi::FailoverPolicy`]).

pub mod multi;

pub use multi::{
    cleartext_tenant_predictions, serve_multi, serve_multi_checked, tenant_query_stream,
    tenant_train_batch, FailoverPolicy, FaultKind, FaultPlan, MultiServeConfig,
    MultiServeStats, OpRollup, QuarantineStats, TenantServeStats, TransitionKind,
    TransitionStats, REHAB_AFTER,
};

use std::collections::VecDeque;

use crate::crypto::Rng;
use crate::ml::{share_fixed_mat, F64Mat};
use crate::net::{Abort, NetProfile, NetReport, Phase, P1, P2};
use crate::obs::Window;
use crate::pool::{
    relu_key_for, CircuitKey, OpKind, Pool, PoolStats, Refill, RefillOutcome, WaterMarks,
};
use crate::proto::{matmul_tr, matmul_tr_keyed, run_4pc, Ctx};
use crate::ring::fixed::{FixedPoint, FRAC_BITS};
use crate::ring::{Matrix, Z64};

/// Domain separators so the model / query streams don't collide.
const W_SEED: u64 = 0x7365_7276_655f_7731;
const Q_SEED: u64 = 0x7365_7276_655f_7131;

/// One inference query: `rows × d` feature rows. The clear values exist
/// only at the data owner; the other parties see the public shape.
#[derive(Clone, Debug)]
pub struct Query {
    pub id: usize,
    pub rows: usize,
    /// Feature rows, present at the data owner only.
    pub x: Option<F64Mat>,
}

/// FIFO request queue with cross-request coalescing: `next_batch` drains up
/// to `coalesce` pending queries into one protocol-level batch.
///
/// This is the single-tenant edge. The multi-tenant path
/// ([`multi::serve_multi`]) replaces it with the deadline/priority-aware
/// [`crate::sched::SchedQueue`] (priority classes, EDF, aging, admission
/// control); both guard `coalesce == 0` as 1 and pop a deterministic
/// trailing partial batch.
pub struct RequestQueue {
    pending: VecDeque<Query>,
    coalesce: usize,
}

impl RequestQueue {
    pub fn new(coalesce: usize) -> RequestQueue {
        RequestQueue { pending: VecDeque::new(), coalesce: coalesce.max(1) }
    }

    pub fn push(&mut self, q: Query) {
        self.pending.push_back(q);
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Pop the next coalesced wave (up to `coalesce` queries), FIFO order.
    pub fn next_batch(&mut self) -> Option<Vec<Query>> {
        if self.pending.is_empty() {
            return None;
        }
        let take = self.coalesce.min(self.pending.len());
        Some(self.pending.drain(..take).collect())
    }
}

/// How the engine sources its offline material (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolMode {
    Inline,
    Scalar,
    Keyed,
}

/// Serving workload configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Feature count.
    pub d: usize,
    /// Rows per query (a client-side mini-batch; 1 = single sample).
    pub rows_per_query: usize,
    /// Number of queries in the workload.
    pub queries: usize,
    /// Max queries coalesced into one protocol batch (1 = the seed's
    /// per-query path).
    pub coalesce: usize,
    /// Offline-material sourcing mode.
    pub mode: PoolMode,
    /// Refill low-water mark, in full-wave items (keyed bundles; scalar
    /// resources are scaled by their per-wave consumption).
    pub low_water: usize,
    /// Refill high-water mark, same units.
    pub high_water: usize,
    /// Apply a batched ReLU after the linear layer (exercises the
    /// bit-extraction pool material).
    pub relu: bool,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            d: 784,
            rows_per_query: 1,
            queries: 8,
            coalesce: 8,
            mode: PoolMode::Keyed,
            low_water: 1,
            high_water: 2,
            relu: false,
            seed: 123,
        }
    }
}

/// The circuit key of the resident linear layer for a wave of `rows`
/// stacked feature rows.
pub fn wave_key(cfg: &ServeConfig, rows: usize) -> CircuitKey {
    CircuitKey {
        model: cfg.seed,
        layer: 0,
        op: OpKind::MatMulTr { shift: FRAC_BITS },
        rows,
        inner: cfg.d,
        cols: 1,
        dealer: P2,
    }
}

/// The coalescing factor actually achievable: `coalesce` capped by the
/// workload size, so a `coalesce > queries` config still registers (and
/// refills) the key real waves will pop rather than an oversized one no
/// wave can ever hit.
fn effective_coalesce(cfg: &ServeConfig) -> usize {
    cfg.coalesce.max(1).min(cfg.queries.max(1))
}

/// The key the engine registers at model load: a **full** coalesced wave.
/// Trailing partial waves key differently and fall back inline.
pub fn model_key(cfg: &ServeConfig) -> CircuitKey {
    wave_key(cfg, effective_coalesce(cfg) * cfg.rows_per_query)
}

/// The paired nonlinear key of a wave of `rows` stacked rows (`relu: true`
/// workloads).
pub fn relu_wave_key(cfg: &ServeConfig, rows: usize) -> CircuitKey {
    relu_key_for(&wave_key(cfg, rows))
}

/// The nonlinear key the engine registers at model load (full wave).
pub fn model_relu_key(cfg: &ServeConfig) -> CircuitKey {
    relu_key_for(&model_key(cfg))
}

/// Per-party output of one serving run (internal).
struct PartyOut {
    /// Per-batch online virtual-time deltas.
    batch_lat: Vec<f64>,
    /// Per-batch online round deltas.
    batch_rounds: Vec<u64>,
    /// Per-batch local-compute seconds (the `timed` closures of the wave:
    /// masked matmuls, truncation, decode) — the `compute_ms` column.
    batch_compute: Vec<f64>,
    /// Per-batch online `Value`-class payload bytes sent by *this* party
    /// (digests/commitments excluded — same class the lemmas count).
    batch_value_bytes: Vec<u64>,
    /// Per-batch offline messages *sent by this party* inside the wave
    /// window (local counters — race-free across threads).
    wave_offline_msgs: Vec<u64>,
    wave_offline_bytes: Vec<u64>,
    /// Per-batch offline messages inside the matrix-gate sub-window
    /// (share → `Π_MatMulTr`) and the ReLU sub-window — attributes the
    /// silence claim per op.
    wave_offline_msgs_mat: Vec<u64>,
    wave_offline_msgs_relu: Vec<u64>,
    /// Refill outcomes, tick order (warm-up tick first).
    refill_outcomes: Vec<RefillOutcome>,
    /// Online messages this party sent inside refill ticks (must be 0:
    /// refill traffic is Phase::Offline only).
    tick_online_msgs: u64,
    /// Decoded predictions, at the data owner only.
    answers: Vec<f64>,
    pool_stats: Option<PoolStats>,
    pool_left_trunc: usize,
    pool_left_mat: usize,
    pool_left_relu: usize,
}

/// Aggregated serving measurements.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub queries: usize,
    pub batches: usize,
    pub rows: usize,
    /// Online rounds of the serving loop (clocks reset after model setup
    /// and pool warm-up).
    pub online_rounds: u64,
    /// Summed per-batch online latency (max across parties per batch).
    pub online_latency: f64,
    /// Online value bits of the serving loop (one-time model sharing
    /// subtracted analytically).
    pub online_value_bits: u64,
    /// Total online bytes of the serving loop, all classes — includes the
    /// amortized verification digests, which is where coalescing shows up
    /// in bytes. The one-time model-share payload is subtracted
    /// analytically; its verification digests travel on directions the
    /// first batch flushes anyway (fixed 32-byte accumulators), so the
    /// serving window is exact.
    pub online_total_bytes: u64,
    /// Offline value bits (pool fill / refill + any live γ exchanges).
    pub offline_value_bits: u64,
    /// Summed per-wave local-compute seconds (max across parties per wave)
    /// — the serving loop's `compute_ms` column, separated from network
    /// latency by the party-local compute meter.
    pub compute_in_waves: f64,
    /// Online `Value`-class payload bytes sent inside wave windows, summed
    /// over parties and waves (digests excluded — comparable to the
    /// analytic value-byte counts) — the per-wave `value_bytes` column.
    pub value_bytes_in_waves: u64,
    /// Offline-phase messages sent by **any** party inside a serving-wave
    /// window, summed over waves — 0 for a warm keyed pool (the
    /// offline-silence property), > 0 whenever a wave runs γ-exchange or
    /// pair generation live.
    pub offline_msgs_in_waves: u64,
    /// Same window, payload bytes.
    pub offline_bytes_in_waves: u64,
    /// The matrix-gate share of `offline_msgs_in_waves` (share →
    /// `Π_MatMulTr` sub-window) — attributes the silence claim per op.
    pub offline_msgs_matmul: u64,
    /// The ReLU share of `offline_msgs_in_waves` (0 when `relu: false`, 0
    /// for warm keyed ReLU bundles, > 0 when `Π_BitExt`/`Π_BitInj` offline
    /// work runs live inside the wave).
    pub offline_msgs_relu: u64,
    /// Refill ticks taken (including the warm-up tick).
    pub refill_ticks: usize,
    /// Keyed matrix bundles generated by refill ticks.
    pub refill_mat_items: usize,
    /// Online messages sent inside refill ticks (refill is offline-only,
    /// so this must be 0; summed over parties).
    pub refill_online_msgs: u64,
    /// Pool counters (None when serving inline).
    pub pool_stats: Option<PoolStats>,
    /// Truncation pairs left unserved in the pool at shutdown.
    pub pool_left_trunc: usize,
    /// Keyed bundles left under the registered model key at shutdown.
    pub pool_left_mat: usize,
    /// Nonlinear bundles left under the registered ReLU key at shutdown
    /// (paired with `pool_left_mat` for `relu: true` keyed workloads).
    pub pool_left_relu: usize,
    /// Online round cost of each coalesced batch (all ~equal: the rounds of
    /// a single query, regardless of how many were coalesced).
    pub rounds_per_batch: Vec<u64>,
    /// Decoded predictions as seen by the data owner, query order.
    pub answers: Vec<f64>,
    pub report: NetReport,
}

impl ServeStats {
    pub fn per_query_latency(&self) -> f64 {
        self.online_latency / self.queries.max(1) as f64
    }

    pub fn per_query_rounds(&self) -> f64 {
        self.online_rounds as f64 / self.queries.max(1) as f64
    }

    pub fn per_query_online_bytes(&self) -> f64 {
        self.online_total_bytes as f64 / self.queries.max(1) as f64
    }

    /// Mean local-compute milliseconds per wave (max across parties).
    pub fn compute_ms_per_wave(&self) -> f64 {
        self.compute_in_waves * 1e3 / self.batches.max(1) as f64
    }

    /// Mean online `Value`-class payload bytes per wave (summed over the
    /// four parties).
    pub fn value_bytes_per_wave(&self) -> f64 {
        self.value_bytes_in_waves as f64 / self.batches.max(1) as f64
    }
}

/// Build the deterministic model weights (at the model owner).
fn model_weights(d: usize, seed: u64) -> F64Mat {
    let mut rng = Rng::seeded(seed ^ W_SEED);
    let mut w = F64Mat::zeros(d, 1);
    for j in 0..d {
        w.set(j, 0, rng.normal() * 0.1);
    }
    w
}

/// Build the deterministic query stream (at the data owner).
fn query_stream(cfg: &ServeConfig) -> Vec<F64Mat> {
    let mut rng = Rng::seeded(cfg.seed ^ Q_SEED);
    (0..cfg.queries)
        .map(|_| {
            let mut x = F64Mat::zeros(cfg.rows_per_query, cfg.d);
            for r in 0..cfg.rows_per_query {
                for c in 0..cfg.d {
                    x.set(r, c, rng.normal());
                }
            }
            x
        })
        .collect()
}

/// Cleartext reference for the workload (test oracle).
pub fn cleartext_predictions(cfg: &ServeConfig) -> Vec<f64> {
    let w = model_weights(cfg.d, cfg.seed);
    let mut out = Vec::new();
    for x in query_stream(cfg) {
        let u = x.matmul(&w);
        for r in 0..cfg.rows_per_query {
            let v = u.at(r, 0);
            out.push(if cfg.relu && v < 0.0 { 0.0 } else { v });
        }
    }
    out
}

/// The per-party serving program.
fn serve_party(ctx: &mut Ctx, cfg: &ServeConfig) -> Result<PartyOut, Abort> {
    // ---- resident model: shared once by the model owner P1, and the
    // sharing verified before any pool material is generated against it ----
    let w0 = (ctx.id() == P1).then(|| model_weights(cfg.d, cfg.seed));
    let w = share_fixed_mat(ctx, P1, w0.as_ref(), cfg.d, 1)?;
    ctx.flush_verify()?;

    // ---- register pool targets with the background refill producer ----
    let wave_rows = effective_coalesce(cfg) * cfg.rows_per_query;
    let mut refill = Refill::new();
    // scalar resources are consumed `wave_rows` items per wave — scale the
    // water marks so one "full-wave item" means the same thing everywhere
    let scaled_marks =
        || WaterMarks::new(cfg.low_water * wave_rows, cfg.high_water.max(1) * wave_rows);
    match cfg.mode {
        PoolMode::Inline => {}
        PoolMode::Scalar => {
            ctx.attach_pool(Pool::new());
            refill.register_trunc(FRAC_BITS, scaled_marks());
            if cfg.relu {
                refill.register_bitext(scaled_marks());
                // one λ_z per bitext_many invocation (its internal Π_Mult)
                refill.register_lam(WaterMarks::new(cfg.low_water, cfg.high_water.max(1)));
            }
        }
        PoolMode::Keyed => {
            ctx.attach_pool(Pool::new());
            let marks = WaterMarks::new(cfg.low_water, cfg.high_water.max(1));
            if cfg.relu {
                // paired matrix + nonlinear bundles: the whole wave —
                // including the ReLU — then drains keyed material and sends
                // zero offline-phase messages
                refill.register_mat_relu(model_key(cfg), model_relu_key(cfg), w.clone(), marks);
            } else {
                refill.register_mat(model_key(cfg), w.clone(), marks);
            }
        }
    }

    let mut out = PartyOut {
        batch_lat: Vec::new(),
        batch_rounds: Vec::new(),
        batch_compute: Vec::new(),
        batch_value_bytes: Vec::new(),
        wave_offline_msgs: Vec::new(),
        wave_offline_bytes: Vec::new(),
        wave_offline_msgs_mat: Vec::new(),
        wave_offline_msgs_relu: Vec::new(),
        refill_outcomes: Vec::new(),
        tick_online_msgs: 0,
        answers: Vec::new(),
        pool_stats: None,
        pool_left_trunc: 0,
        pool_left_mat: 0,
        pool_left_relu: 0,
    };

    // warm-up: the first "between waves" slot is before the first wave
    let tick = |ctx: &mut Ctx, out: &mut PartyOut| -> Result<(), Abort> {
        let w = Window::open(ctx.net);
        let outcome = refill.tick(ctx)?;
        out.tick_online_msgs += w.diff(ctx.net).msgs(Phase::Online);
        out.refill_outcomes.push(outcome);
        Ok(())
    };
    if cfg.mode != PoolMode::Inline {
        tick(ctx, &mut out)?;
    }

    // ---- request queue (values at the data owner P2 only) ----
    let mut queue = RequestQueue::new(cfg.coalesce);
    let xs_clear = (ctx.id() == P2).then(|| query_stream(cfg));
    for id in 0..cfg.queries {
        queue.push(Query {
            id,
            rows: cfg.rows_per_query,
            x: xs_clear.as_ref().map(|v| v[id].clone()),
        });
    }

    // ---- serving loop, measured in isolation ----
    ctx.net.reset_clocks();
    while let Some(batch) = queue.next_batch() {
        let rows: usize = batch.iter().map(|q| q.rows).sum();
        // one Window covers every per-batch meter the old six hand-kept
        // snapshots tracked (saturating diffs, phase-indexed)
        let bw = Window::open(ctx.net);

        // stack the wave into one cross-request matrix
        let stacked: Option<F64Mat> = (ctx.id() == P2).then(|| {
            let mut m = F64Mat::zeros(rows, cfg.d);
            let mut row = 0;
            for q in &batch {
                let x = q.x.as_ref().expect("data owner holds query rows");
                for r in 0..q.rows {
                    for c in 0..cfg.d {
                        m.set(row, c, x.at(r, c));
                    }
                    row += 1;
                }
            }
            m
        });

        // one truncated matmul for the whole wave
        let mut u = match cfg.mode {
            PoolMode::Keyed => {
                let key = wave_key(cfg, rows);
                let x_enc: Option<Matrix<Z64>> = stacked.as_ref().map(F64Mat::encode);
                let (_x, u) = matmul_tr_keyed(ctx, &key, x_enc.as_ref(), &w)?;
                u
            }
            _ => {
                let x_sh = share_fixed_mat(ctx, P2, stacked.as_ref(), rows, cfg.d)?;
                matmul_tr(ctx, &x_sh, &w)?
            }
        };
        let om_mat = bw.diff(ctx.net).msgs(Phase::Offline);
        let wr = Window::open(ctx.net);
        if cfg.relu {
            // flat path: the wave stays on SoA matrices; the share-vector
            // conversion lives inside the mat-level ReLU entry points
            u = match cfg.mode {
                PoolMode::Keyed => {
                    crate::ml::relu_mat_keyed(ctx, &relu_wave_key(cfg, rows), &u)?.0
                }
                _ => crate::ml::relu_mat(ctx, &u)?.0,
            };
        }
        let om_relu = wr.diff(ctx.net).msgs(Phase::Offline);

        // deliver: open towards the data owner, flushing verification —
        // SoA reconstruction, no per-element share vector
        let opened = crate::proto::reconstruct::reconstruct_mat_to(ctx, &u, &[P2])?;
        if let Some(vals) = opened {
            out.answers.extend(vals.data().iter().map(|&v| FixedPoint::decode(v)));
        }

        let d = bw.diff(ctx.net);
        out.batch_lat.push(d.clock(Phase::Online));
        out.batch_rounds.push(d.rounds(Phase::Online));
        out.batch_compute.push(d.compute(Phase::Online));
        out.batch_value_bytes.push(d.value_bytes(Phase::Online));
        out.wave_offline_msgs.push(d.msgs(Phase::Offline));
        out.wave_offline_bytes.push(d.bytes(Phase::Offline));
        out.wave_offline_msgs_mat.push(om_mat);
        out.wave_offline_msgs_relu.push(om_relu);

        // between waves: the background producer tops the pools back up —
        // but only while a full wave remains; a trailing partial wave keys
        // differently and falls back inline, so refilling for it would only
        // strand a full-wave bundle in the pool
        if cfg.mode != PoolMode::Inline && queue.len() >= effective_coalesce(cfg) {
            tick(ctx, &mut out)?;
        }
    }

    if let Some(pool) = ctx.detach_pool() {
        out.pool_stats = Some(pool.stats());
        out.pool_left_trunc = pool.len_trunc(FRAC_BITS);
        out.pool_left_mat = pool.len_mat(&model_key(cfg));
        out.pool_left_relu = pool.len_relu(&model_relu_key(cfg));
    }
    Ok(out)
}

/// Run the serving workload over `profile` and aggregate measurements.
pub fn serve(profile: NetProfile, cfg: ServeConfig) -> ServeStats {
    let cfg2 = cfg.clone();
    let run = run_4pc(profile, cfg.seed, move |ctx| serve_party(ctx, &cfg2));
    let (outs, report) = run.expect_ok();

    let batches = outs[1].batch_lat.len();
    let mut online_latency = 0.0;
    let mut compute_in_waves = 0.0;
    for i in 0..batches {
        let batch_max = outs
            .iter()
            .map(|o| o.batch_lat[i])
            .fold(0.0f64, f64::max);
        online_latency += batch_max;
        compute_in_waves += outs
            .iter()
            .map(|o| o.batch_compute[i])
            .fold(0.0f64, f64::max);
    }
    let value_bytes_in_waves: u64 =
        outs.iter().map(|o| o.batch_value_bytes.iter().sum::<u64>()).sum();
    let w_share_bits = 2 * cfg.d as u64 * 64; // one-time model sharing
    let offline_msgs_in_waves: u64 =
        outs.iter().map(|o| o.wave_offline_msgs.iter().sum::<u64>()).sum();
    let offline_bytes_in_waves: u64 =
        outs.iter().map(|o| o.wave_offline_bytes.iter().sum::<u64>()).sum();
    let offline_msgs_matmul: u64 =
        outs.iter().map(|o| o.wave_offline_msgs_mat.iter().sum::<u64>()).sum();
    let offline_msgs_relu: u64 =
        outs.iter().map(|o| o.wave_offline_msgs_relu.iter().sum::<u64>()).sum();
    ServeStats {
        queries: cfg.queries,
        batches,
        rows: cfg.queries * cfg.rows_per_query,
        online_rounds: report.rounds[Phase::Online as usize],
        online_latency,
        online_value_bits: report.value_bits[Phase::Online as usize]
            .saturating_sub(w_share_bits),
        online_total_bytes: report.total_bytes[Phase::Online as usize]
            .saturating_sub(w_share_bits / 8),
        offline_value_bits: report.value_bits[Phase::Offline as usize],
        compute_in_waves,
        value_bytes_in_waves,
        offline_msgs_in_waves,
        offline_bytes_in_waves,
        offline_msgs_matmul,
        offline_msgs_relu,
        refill_ticks: outs[1].refill_outcomes.len(),
        refill_mat_items: outs[1].refill_outcomes.iter().map(|o| o.mat_items).sum(),
        refill_online_msgs: outs.iter().map(|o| o.tick_online_msgs).sum(),
        pool_stats: outs[1].pool_stats,
        pool_left_trunc: outs[1].pool_left_trunc,
        pool_left_mat: outs[1].pool_left_mat,
        pool_left_relu: outs[1].pool_left_relu,
        rounds_per_batch: outs[1].batch_rounds.clone(),
        answers: outs[2].answers.clone(),
        report,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(queries: usize, coalesce: usize, mode: PoolMode) -> ServeConfig {
        ServeConfig {
            d: 16,
            rows_per_query: 2,
            queries,
            coalesce,
            mode,
            low_water: 1,
            high_water: 1,
            relu: false,
            seed: 900,
        }
    }

    #[test]
    fn serving_answers_match_cleartext() {
        for (mode, coalesce) in
            [(PoolMode::Inline, 1), (PoolMode::Scalar, 4), (PoolMode::Keyed, 4)]
        {
            let c = cfg(4, coalesce, mode);
            let stats = serve(NetProfile::zero(), c.clone());
            let want = cleartext_predictions(&c);
            assert_eq!(stats.answers.len(), want.len());
            for (i, (got, want)) in stats.answers.iter().zip(&want).enumerate() {
                assert!(
                    (got - want).abs() < 0.01,
                    "query row {i}: got {got}, want {want} ({mode:?})"
                );
            }
        }
    }

    #[test]
    fn coalesced_wave_costs_one_querys_rounds() {
        // N coalesced queries: same online rounds as a single query
        let one = serve(NetProfile::zero(), cfg(1, 1, PoolMode::Keyed));
        let wave = serve(NetProfile::zero(), cfg(6, 6, PoolMode::Keyed));
        assert_eq!(wave.batches, 1);
        assert_eq!(
            wave.online_rounds, one.online_rounds,
            "coalescing must not add rounds"
        );
        // the seed's per-query path pays per query
        let inline = serve(NetProfile::zero(), cfg(6, 1, PoolMode::Inline));
        assert_eq!(inline.online_rounds, 6 * one.online_rounds);
    }

    #[test]
    fn keyed_pool_drains_and_refills_during_serving() {
        // low == high == 1: fill 1 → pop → refill 1 → pop → … (the
        // tightest refill cadence; also proves a refill between pops never
        // interleaves material inside a pop)
        let stats = serve(NetProfile::zero(), cfg(4, 2, PoolMode::Keyed));
        let ps = stats.pool_stats.expect("pool attached");
        assert_eq!(ps.mat_hits, 2, "both waves must drain a keyed bundle: {ps:?}");
        assert_eq!(ps.mat_misses, 0);
        assert_eq!(stats.refill_ticks, 2, "warm-up tick + one between-waves tick");
        assert_eq!(stats.refill_mat_items, 2);
        assert_eq!(stats.refill_online_msgs, 0, "refill traffic is offline-only");
        assert_eq!(stats.pool_left_mat, 0, "no tick after the last wave");
    }

    #[test]
    fn wave_compute_and_bytes_metrics_populate() {
        let stats = serve(NetProfile::zero(), cfg(4, 2, PoolMode::Keyed));
        assert_eq!(stats.batches, 2);
        assert!(stats.value_bytes_in_waves > 0, "waves send value payload");
        assert!(stats.value_bytes_per_wave() > 0.0);
        // Value class only: the wave windows must not book more value
        // bytes than the whole run's value traffic
        assert!(
            stats.value_bytes_in_waves
                <= stats.report.value_bytes[Phase::Online as usize],
            "per-wave value bytes exclude digest traffic"
        );
        assert!(stats.compute_in_waves >= 0.0);
        assert!(stats.compute_ms_per_wave().is_finite());
    }

    #[test]
    fn scalar_pool_drains_during_serving() {
        let stats = serve(NetProfile::zero(), cfg(4, 2, PoolMode::Scalar));
        let ps = stats.pool_stats.expect("pool attached");
        assert!(ps.trunc_hits >= 2, "trunc pairs must come from the pool: {ps:?}");
    }

    #[test]
    fn relu_serving_uses_bitext_pool() {
        for mode in [PoolMode::Scalar, PoolMode::Keyed] {
            let mut c = cfg(2, 2, mode);
            c.relu = true;
            let stats = serve(NetProfile::zero(), c.clone());
            let ps = stats.pool_stats.expect("pool attached");
            match mode {
                // scalar: position-independent masks from the typed queue
                PoolMode::Scalar => {
                    assert!(ps.bitext_hits >= 1, "relu must drain bitext masks: {ps:?}")
                }
                // keyed: the wave drains one whole nonlinear bundle instead
                _ => assert!(ps.relu_hits >= 1, "relu must drain keyed bundles: {ps:?}"),
            }
            let want = cleartext_predictions(&c);
            for (got, want) in stats.answers.iter().zip(&want) {
                assert!((got - want).abs() < 0.01, "relu serving ({mode:?}): {got} vs {want}");
            }
        }
    }

    #[test]
    fn keyed_relu_wave_drains_paired_bundles_and_strands_nothing() {
        // 4 queries at coalesce 2 → two full relu waves: each drains one
        // matrix + one nonlinear bundle; refill tops both up in pairs and
        // nothing is stranded at shutdown
        let mut c = cfg(4, 2, PoolMode::Keyed);
        c.relu = true;
        let stats = serve(NetProfile::zero(), c.clone());
        let ps = stats.pool_stats.expect("pool attached");
        assert_eq!(ps.mat_hits, 2, "both waves drain a matrix bundle: {ps:?}");
        assert_eq!(ps.relu_hits, 2, "both waves drain a nonlinear bundle: {ps:?}");
        assert_eq!(ps.relu_misses, 0);
        assert_eq!(ps.bitext_hits, 0, "keyed mode never touches the typed bitext queue");
        assert_eq!(stats.pool_left_mat, 0);
        assert_eq!(stats.pool_left_relu, 0, "paired queues drain in lockstep");
        let want = cleartext_predictions(&c);
        for (got, want) in stats.answers.iter().zip(&want) {
            assert!((got - want).abs() < 0.01, "keyed relu wave: {got} vs {want}");
        }
    }

    #[test]
    fn oversized_coalesce_still_hits_keyed_pool() {
        // coalesce 8 > queries 2: the registered key must match the wave the
        // workload can actually produce (2 queries · 2 rows), not a
        // never-popped 8-query shape
        let c = cfg(2, 8, PoolMode::Keyed);
        let stats = serve(NetProfile::zero(), c.clone());
        let ps = stats.pool_stats.expect("pool attached");
        assert_eq!(ps.mat_hits, 1, "the single wave must hit the keyed pool: {ps:?}");
        assert_eq!(ps.mat_misses, 0);
        let want = cleartext_predictions(&c);
        for (got, want) in stats.answers.iter().zip(&want) {
            assert!((got - want).abs() < 0.01, "oversized-coalesce wave: {got} vs {want}");
        }
    }

    #[test]
    fn partial_trailing_wave_falls_back_inline() {
        // 5 queries, coalesce 2 → waves of 2,2,1: the 1-query wave keys
        // differently from the registered full-wave key and must fall back
        // inline — deterministically, with correct answers.
        let c = cfg(5, 2, PoolMode::Keyed);
        let stats = serve(NetProfile::zero(), c.clone());
        let ps = stats.pool_stats.expect("pool attached");
        assert_eq!(ps.mat_hits, 2);
        assert_eq!(ps.mat_misses, 1, "partial wave is a keyed miss: {ps:?}");
        assert_eq!(
            stats.pool_left_mat, 0,
            "no full-wave bundle may be stranded for a partial trailing wave"
        );
        let want = cleartext_predictions(&c);
        for (got, want) in stats.answers.iter().zip(&want) {
            assert!((got - want).abs() < 0.01, "fallback wave: {got} vs {want}");
        }
    }

    #[test]
    fn request_queue_fifo_and_coalescing() {
        let mut q = RequestQueue::new(3);
        for id in 0..7 {
            q.push(Query { id, rows: 1, x: None });
        }
        let b1 = q.next_batch().unwrap();
        assert_eq!(b1.iter().map(|q| q.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.next_batch().unwrap().len(), 3);
        assert_eq!(q.next_batch().unwrap().len(), 1);
        assert!(q.next_batch().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn request_queue_guards_coalesce_zero_and_pops_deterministic_trailing_batch() {
        // coalesce 0 must behave as 1, not drain nothing / divide by zero
        let mut q = RequestQueue::new(0);
        for id in 0..2 {
            q.push(Query { id, rows: 1, x: None });
        }
        let b = q.next_batch().unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].id, 0);
        assert_eq!(q.next_batch().unwrap()[0].id, 1);
        assert!(q.next_batch().is_none());
        // a coalesce-0 ServeConfig registers the 1-query wave key, so real
        // waves still hit the pool instead of always falling back inline
        let c = ServeConfig { coalesce: 0, queries: 2, ..ServeConfig::default() };
        assert_eq!(model_key(&c).rows, c.rows_per_query);
        // trailing partial batch: 5 queries at coalesce 2 always pop as
        // [0,1], [2,3], [4] — byte-for-byte the same schedule every run
        let mut q = RequestQueue::new(2);
        for id in 0..5 {
            q.push(Query { id, rows: 1, x: None });
        }
        let ids: Vec<Vec<usize>> = std::iter::from_fn(|| q.next_batch())
            .map(|b| b.iter().map(|q| q.id).collect())
            .collect();
        assert_eq!(ids, vec![vec![0, 1], vec![2, 3], vec![4]]);
    }
}
