//! Batched online serving engine — the MLaaS loop of §I run the way the
//! paper's offline/online split intends: all input-independent work
//! pre-generated into the [`crate::pool`], concurrent inference queries
//! coalesced into cross-request batches so a whole wave of traffic costs
//! one protocol round-trip, and per-query amortized cost reported through
//! the existing meter.
//!
//! Pipeline per coalesced batch:
//!
//! 1. [`RequestQueue::next_batch`] pops up to `coalesce` pending queries
//!    and stacks their feature rows into one matrix;
//! 2. the data owner `Π_Sh`-shares the stacked matrix (one round for the
//!    whole wave);
//! 3. one `Π_MatMulTr` against the resident model (one round; truncation
//!    pairs drained from the pool, so the per-request offline cost is the
//!    γ-exchange only), optionally followed by a batched ReLU;
//! 4. predictions are reconstructed towards the data owner and the batched
//!    verification digests are flushed — every response is verified before
//!    release.
//!
//! Rounds per batch are therefore **independent of how many queries were
//! coalesced**; the per-query amortized rounds/latency/verification bytes
//! shrink ~linearly in the coalescing factor (asserted by the meter
//! regression tests and printed by `bench::serve_table` /
//! `benches/serving.rs`).

use std::collections::VecDeque;

use crate::crypto::Rng;
use crate::ml::{share_fixed_mat, F64Mat};
use crate::net::{Abort, NetProfile, NetReport, Phase, P1, P2};
use crate::pool::{self, Pool, PoolStats};
use crate::proto::{matmul_tr, run_4pc, Ctx};
use crate::ring::fixed::{FixedPoint, FRAC_BITS};
use crate::ring::Z64;
use crate::sharing::MMat;

/// Domain separators so the model / query streams don't collide.
const W_SEED: u64 = 0x7365_7276_655f_7731;
const Q_SEED: u64 = 0x7365_7276_655f_7131;

/// One inference query: `rows × d` feature rows. The clear values exist
/// only at the data owner; the other parties see the public shape.
#[derive(Clone, Debug)]
pub struct Query {
    pub id: usize,
    pub rows: usize,
    /// Feature rows, present at the data owner only.
    pub x: Option<F64Mat>,
}

/// FIFO request queue with cross-request coalescing: `next_batch` drains up
/// to `coalesce` pending queries into one protocol-level batch.
pub struct RequestQueue {
    pending: VecDeque<Query>,
    coalesce: usize,
}

impl RequestQueue {
    pub fn new(coalesce: usize) -> RequestQueue {
        RequestQueue { pending: VecDeque::new(), coalesce: coalesce.max(1) }
    }

    pub fn push(&mut self, q: Query) {
        self.pending.push_back(q);
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Pop the next coalesced wave (up to `coalesce` queries), FIFO order.
    pub fn next_batch(&mut self) -> Option<Vec<Query>> {
        if self.pending.is_empty() {
            return None;
        }
        let take = self.coalesce.min(self.pending.len());
        Some(self.pending.drain(..take).collect())
    }
}

/// Serving workload configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Feature count.
    pub d: usize,
    /// Rows per query (a client-side mini-batch; 1 = single sample).
    pub rows_per_query: usize,
    /// Number of queries in the workload.
    pub queries: usize,
    /// Max queries coalesced into one protocol batch (1 = the seed's
    /// per-query path).
    pub coalesce: usize,
    /// Pre-stock the offline pool before serving starts.
    pub pool: bool,
    /// Apply a batched ReLU after the linear layer (exercises the
    /// bit-extraction pool material).
    pub relu: bool,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            d: 784,
            rows_per_query: 1,
            queries: 8,
            coalesce: 8,
            pool: true,
            relu: false,
            seed: 123,
        }
    }
}

/// Per-party output of one serving run (internal).
struct PartyOut {
    /// Per-batch online virtual-time deltas.
    batch_lat: Vec<f64>,
    /// Per-batch online round deltas.
    batch_rounds: Vec<u64>,
    /// Decoded predictions, at the data owner only.
    answers: Vec<f64>,
    pool_stats: Option<PoolStats>,
    pool_left_trunc: usize,
}

/// Aggregated serving measurements.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub queries: usize,
    pub batches: usize,
    pub rows: usize,
    /// Online rounds of the serving loop (clocks reset after model setup
    /// and pool fill).
    pub online_rounds: u64,
    /// Summed per-batch online latency (max across parties per batch).
    pub online_latency: f64,
    /// Online value bits of the serving loop (one-time model sharing
    /// subtracted analytically).
    pub online_value_bits: u64,
    /// Total online bytes of the serving loop, all classes — includes the
    /// amortized verification digests, which is where coalescing shows up
    /// in bytes. The one-time model-share payload is subtracted
    /// analytically; its verification digests travel on directions the
    /// first batch flushes anyway (fixed 32-byte accumulators), so the
    /// serving window is exact.
    pub online_total_bytes: u64,
    /// Offline value bits (pool fill + per-batch γ exchanges).
    pub offline_value_bits: u64,
    /// Pool counters (None when serving inline).
    pub pool_stats: Option<PoolStats>,
    /// Truncation pairs left unserved in the pool at shutdown.
    pub pool_left_trunc: usize,
    /// Online round cost of each coalesced batch (all ~equal: the rounds of
    /// a single query, regardless of how many were coalesced).
    pub rounds_per_batch: Vec<u64>,
    /// Decoded predictions as seen by the data owner, query order.
    pub answers: Vec<f64>,
    pub report: NetReport,
}

impl ServeStats {
    pub fn per_query_latency(&self) -> f64 {
        self.online_latency / self.queries.max(1) as f64
    }

    pub fn per_query_rounds(&self) -> f64 {
        self.online_rounds as f64 / self.queries.max(1) as f64
    }

    pub fn per_query_online_bytes(&self) -> f64 {
        self.online_total_bytes as f64 / self.queries.max(1) as f64
    }
}

/// Build the deterministic model weights (at the model owner).
fn model_weights(d: usize, seed: u64) -> F64Mat {
    let mut rng = Rng::seeded(seed ^ W_SEED);
    let mut w = F64Mat::zeros(d, 1);
    for j in 0..d {
        w.set(j, 0, rng.normal() * 0.1);
    }
    w
}

/// Build the deterministic query stream (at the data owner).
fn query_stream(cfg: &ServeConfig) -> Vec<F64Mat> {
    let mut rng = Rng::seeded(cfg.seed ^ Q_SEED);
    (0..cfg.queries)
        .map(|_| {
            let mut x = F64Mat::zeros(cfg.rows_per_query, cfg.d);
            for r in 0..cfg.rows_per_query {
                for c in 0..cfg.d {
                    x.set(r, c, rng.normal());
                }
            }
            x
        })
        .collect()
}

/// Cleartext reference for the workload (test oracle).
pub fn cleartext_predictions(cfg: &ServeConfig) -> Vec<f64> {
    let w = model_weights(cfg.d, cfg.seed);
    let mut out = Vec::new();
    for x in query_stream(cfg) {
        let u = x.matmul(&w);
        for r in 0..cfg.rows_per_query {
            let v = u.at(r, 0);
            out.push(if cfg.relu && v < 0.0 { 0.0 } else { v });
        }
    }
    out
}

/// The per-party serving program.
fn serve_party(ctx: &mut Ctx, cfg: &ServeConfig) -> Result<PartyOut, Abort> {
    // ---- resident model: shared once by the model owner P1 ----
    let w0 = (ctx.id() == P1).then(|| model_weights(cfg.d, cfg.seed));
    let w = share_fixed_mat(ctx, P1, w0.as_ref(), cfg.d, 1)?;

    // ---- offline pre-stocking ----
    let total_rows = cfg.queries * cfg.rows_per_query;
    let coalesce = cfg.coalesce.max(1);
    let batches = (cfg.queries + coalesce - 1) / coalesce;
    if cfg.pool {
        ctx.attach_pool(Pool::new());
        pool::fill_trunc(ctx, total_rows, FRAC_BITS)?;
        if cfg.relu {
            pool::fill_bitext(ctx, total_rows)?;
            // one λ_z per bitext_many invocation (its internal Π_Mult)
            pool::fill_lam::<Z64>(ctx, batches);
        }
    }

    // ---- request queue (values at the data owner P2 only) ----
    let mut queue = RequestQueue::new(cfg.coalesce);
    let xs_clear = (ctx.id() == P2).then(|| query_stream(cfg));
    for id in 0..cfg.queries {
        queue.push(Query {
            id,
            rows: cfg.rows_per_query,
            x: xs_clear.as_ref().map(|v| v[id].clone()),
        });
    }

    // ---- serving loop, measured in isolation ----
    ctx.net.reset_clocks();
    let mut out = PartyOut {
        batch_lat: Vec::new(),
        batch_rounds: Vec::new(),
        answers: Vec::new(),
        pool_stats: None,
        pool_left_trunc: 0,
    };
    while let Some(batch) = queue.next_batch() {
        let rows: usize = batch.iter().map(|q| q.rows).sum();
        let t0 = ctx.net.clock(Phase::Online);
        let r0 = ctx.net.rounds(Phase::Online);

        // stack the wave into one cross-request matrix
        let stacked: Option<F64Mat> = (ctx.id() == P2).then(|| {
            let mut m = F64Mat::zeros(rows, cfg.d);
            let mut row = 0;
            for q in &batch {
                let x = q.x.as_ref().expect("data owner holds query rows");
                for r in 0..q.rows {
                    for c in 0..cfg.d {
                        m.set(row, c, x.at(r, c));
                    }
                    row += 1;
                }
            }
            m
        });
        let x_sh = share_fixed_mat(ctx, P2, stacked.as_ref(), rows, cfg.d)?;

        // one truncated matmul for the whole wave
        let mut u = matmul_tr(ctx, &x_sh, &w)?;
        if cfg.relu {
            let (r, _) = crate::ml::relu_many(ctx, &u.to_shares())?;
            u = MMat::from_shares(rows, 1, &r);
        }

        // deliver: open towards the data owner, flushing verification
        let opened =
            crate::proto::reconstruct::reconstruct_to_many(ctx, &u.to_shares(), &[P2])?;
        if let Some(vals) = opened {
            out.answers.extend(vals.iter().map(|&v| FixedPoint::decode(v)));
        }

        out.batch_lat.push(ctx.net.clock(Phase::Online) - t0);
        out.batch_rounds.push(ctx.net.rounds(Phase::Online) - r0);
    }

    if let Some(pool) = ctx.detach_pool() {
        out.pool_stats = Some(pool.stats());
        out.pool_left_trunc = pool.len_trunc(FRAC_BITS);
    }
    Ok(out)
}

/// Run the serving workload over `profile` and aggregate measurements.
pub fn serve(profile: NetProfile, cfg: ServeConfig) -> ServeStats {
    let cfg2 = cfg.clone();
    let run = run_4pc(profile, cfg.seed, move |ctx| serve_party(ctx, &cfg2));
    let (outs, report) = run.expect_ok();

    let batches = outs[1].batch_lat.len();
    let mut online_latency = 0.0;
    for i in 0..batches {
        let batch_max = outs
            .iter()
            .map(|o| o.batch_lat[i])
            .fold(0.0f64, f64::max);
        online_latency += batch_max;
    }
    let w_share_bits = 2 * cfg.d as u64 * 64; // one-time model sharing
    ServeStats {
        queries: cfg.queries,
        batches,
        rows: cfg.queries * cfg.rows_per_query,
        online_rounds: report.rounds[Phase::Online as usize],
        online_latency,
        online_value_bits: report.value_bits[Phase::Online as usize]
            .saturating_sub(w_share_bits),
        online_total_bytes: report.total_bytes[Phase::Online as usize]
            .saturating_sub(w_share_bits / 8),
        offline_value_bits: report.value_bits[Phase::Offline as usize],
        pool_stats: outs[1].pool_stats,
        pool_left_trunc: outs[1].pool_left_trunc,
        rounds_per_batch: outs[1].batch_rounds.clone(),
        answers: outs[2].answers.clone(),
        report,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(queries: usize, coalesce: usize, pool: bool) -> ServeConfig {
        ServeConfig {
            d: 16,
            rows_per_query: 2,
            queries,
            coalesce,
            pool,
            relu: false,
            seed: 900,
        }
    }

    #[test]
    fn serving_answers_match_cleartext() {
        for (pool, coalesce) in [(false, 1), (true, 4)] {
            let c = cfg(4, coalesce, pool);
            let stats = serve(NetProfile::zero(), c.clone());
            let want = cleartext_predictions(&c);
            assert_eq!(stats.answers.len(), want.len());
            for (i, (got, want)) in stats.answers.iter().zip(&want).enumerate() {
                assert!(
                    (got - want).abs() < 0.01,
                    "query row {i}: got {got}, want {want} (pool={pool})"
                );
            }
        }
    }

    #[test]
    fn coalesced_wave_costs_one_querys_rounds() {
        // N coalesced queries: same online rounds as a single query
        let one = serve(NetProfile::zero(), cfg(1, 1, true));
        let wave = serve(NetProfile::zero(), cfg(6, 6, true));
        assert_eq!(wave.batches, 1);
        assert_eq!(
            wave.online_rounds, one.online_rounds,
            "coalescing must not add rounds"
        );
        // the seed's per-query path pays per query
        let inline = serve(NetProfile::zero(), cfg(6, 1, false));
        assert_eq!(inline.online_rounds, 6 * one.online_rounds);
    }

    #[test]
    fn pool_drains_during_serving() {
        let stats = serve(NetProfile::zero(), cfg(4, 2, true));
        let ps = stats.pool_stats.expect("pool attached");
        assert!(ps.trunc_hits >= 2, "trunc pairs must come from the pool: {ps:?}");
        assert_eq!(stats.pool_left_trunc, 0, "pool sized to the workload drains fully");
    }

    #[test]
    fn relu_serving_uses_bitext_pool() {
        let mut c = cfg(2, 2, true);
        c.relu = true;
        let stats = serve(NetProfile::zero(), c.clone());
        let ps = stats.pool_stats.expect("pool attached");
        assert!(ps.bitext_hits >= 1, "relu must drain bitext masks: {ps:?}");
        let want = cleartext_predictions(&c);
        for (got, want) in stats.answers.iter().zip(&want) {
            assert!((got - want).abs() < 0.01, "relu serving: {got} vs {want}");
        }
    }

    #[test]
    fn request_queue_fifo_and_coalescing() {
        let mut q = RequestQueue::new(3);
        for id in 0..7 {
            q.push(Query { id, rows: 1, x: None });
        }
        let b1 = q.next_batch().unwrap();
        assert_eq!(b1.iter().map(|q| q.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.next_batch().unwrap().len(), 3);
        assert_eq!(q.next_batch().unwrap().len(), 1);
        assert!(q.next_batch().is_none());
        assert!(q.is_empty());
    }
}
