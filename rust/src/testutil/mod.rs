//! Test utilities: protocol-level helpers and a miniature property-testing
//! harness (the image has no `proptest`; [`forall`] covers the
//! generate-check-shrink loop we need for coordinator invariants).

use crate::crypto::Rng;
use crate::net::{Abort, PartyId};
use crate::proto::{sharing::share_mat_n, Ctx};
use crate::ring::{Matrix, Ring, Z64};
use crate::sharing::MMat;

/// Share a matrix from `dealer` inside a party program (every party passes
/// the same matrix; only the dealer's values are used).
pub fn share_mat(
    ctx: &mut Ctx,
    dealer: PartyId,
    m: &Matrix<Z64>,
) -> Result<MMat<Z64>, Abort> {
    share_mat_r(ctx, dealer, m)
}

/// Share a generic ring matrix from `dealer`.
pub fn share_mat_r<R: Ring>(
    ctx: &mut Ctx,
    dealer: PartyId,
    m: &Matrix<R>,
) -> Result<MMat<R>, Abort> {
    share_mat_n(ctx, dealer, (ctx.id() == dealer).then_some(m), m.rows(), m.cols())
}

/// Mini property-test driver: run `check` on `iters` random inputs drawn by
/// `gen`; on failure, greedily shrink with `shrink` and report the smallest
/// failing case.
pub fn forall<T: Clone + std::fmt::Debug>(
    seed: u64,
    iters: usize,
    gen: impl Fn(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    check: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::seeded(seed);
    for i in 0..iters {
        let case = gen(&mut rng);
        if let Err(first_err) = check(&case) {
            // greedy shrink
            let mut cur = case.clone();
            let mut err = first_err;
            'outer: loop {
                for cand in shrink(&cur) {
                    if let Err(e) = check(&cand) {
                        cur = cand;
                        err = e;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!("property failed (iter {i})\n  minimal case: {cur:?}\n  error: {err}");
        }
    }
}

/// Common shrinker for vectors: halves and single-element removals.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
        let mut less = v.to_vec();
        less.pop();
        out.push(less);
    }
    out
}

/// Common shrinker for u64 values: toward zero.
pub fn shrink_u64(v: u64) -> Vec<u64> {
    let mut out = Vec::new();
    if v > 0 {
        out.push(v / 2);
        out.push(v - 1);
        if v > 0xFF {
            out.push(v & 0xFF);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_on_true_property() {
        forall(
            1,
            100,
            |rng| rng.next_u64(),
            |&v| shrink_u64(v),
            |&v| {
                if v.wrapping_add(0) == v {
                    Ok(())
                } else {
                    Err("identity".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_shrinks_to_minimal() {
        forall(
            2,
            100,
            |rng| rng.below(1000),
            |&v| shrink_u64(v),
            |&v| if v < 500 { Ok(()) } else { Err(format!("{v} too big")) },
        );
    }
}
