//! Trident CLI — leader entrypoint.
//!
//! ```text
//! trident quickstart                   # share → multiply → reconstruct demo
//! trident train   [--model nn|cnn|linreg|logreg] [--iters N] [--batch B] [--features D]
//! trident predict [--model ...] [--batch B]
//! trident tables  [table1 ... fig20 serve serve-tenants] [--json]
//!                                      # regenerate the paper's evaluation
//! trident serve   [--queries N] [--coalesce C] [--mode inline|scalar|keyed]
//!                 [--low-water L] [--high-water H] [--relu] [--json]
//!                                      # batched prediction serving demo
//! trident serve   --models m1,m2 [--weights 2,1] [--priorities 0,1]
//!                 [--deadline-ms D] [--cap N] [--queries N] [--coalesce C]
//!                 [--low-water L] [--high-water H] [--containment] [--json]
//!                 [--trace out.jsonl]
//!                                      # multi-tenant scheduler demo;
//!                                      # --containment injects a mid-serve
//!                                      # tamper fault and quarantines the
//!                                      # poisoned tenant instead of dying;
//!                                      # --trace writes the four-party
//!                                      # event stream as JSONL
//! trident metrics                      # Prometheus-style text snapshot of
//!                                      # the traced demo serving run
//! ```
//!
//! `--json` (serve / tables) additionally writes the machine-readable
//! serving benchmark to `BENCH_serving.json` at the repo root.

use std::collections::HashMap;

/// Parse a comma-separated numeric flag **positionally**: an unparsable
/// entry keeps its slot (with `default` and a warning) instead of being
/// dropped, so later values never shift onto the wrong model.
fn parse_num_list<T>(raw: Option<&String>, key: &str, default: T) -> Vec<T>
where
    T: std::str::FromStr + Copy + std::fmt::Display,
{
    match raw {
        None => Vec::new(),
        Some(v) => v
            .split(',')
            .enumerate()
            .map(|(i, tok)| {
                tok.trim().parse().unwrap_or_else(|_| {
                    println!("--{key} entry {i} ({tok:?}) is not a number; using {default}");
                    default
                })
            })
            .collect(),
    }
}

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    let cmd = pos.first().map(String::as_str).unwrap_or("help");
    let pjrt = trident::runtime::pjrt::init_default();

    match cmd {
        "quickstart" => {
            trident::coordinator::demo_quickstart();
        }
        "train" => {
            let model = flags.get("model").map(String::as_str).unwrap_or("nn");
            let iters: usize = flags.get("iters").and_then(|v| v.parse().ok()).unwrap_or(10);
            let batch: usize = flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(128);
            let d: usize = flags.get("features").and_then(|v| v.parse().ok()).unwrap_or(784);
            trident::coordinator::train_cli(model, iters, batch, d);
        }
        "predict" => {
            let model = flags.get("model").map(String::as_str).unwrap_or("nn");
            let batch: usize = flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(100);
            trident::coordinator::predict_cli(model, batch);
        }
        "tables" => {
            println!("pjrt: {}", if pjrt { "enabled" } else { "native fallback" });
            let filter: Vec<String> = pos[1..].to_vec();
            print!("{}", trident::bench::run_tables(&filter));
            if flags.get("json").map(String::as_str) == Some("true") {
                match trident::bench::write_serving_bench_json("BENCH_serving.json") {
                    Ok(_) => println!("wrote BENCH_serving.json"),
                    Err(e) => println!("could not write BENCH_serving.json: {e}"),
                }
            }
        }
        "serve" => {
            let json = flags.get("json").map(String::as_str) == Some("true");
            if let Some(models) = flags.get("models") {
                // multi-tenant path: the scheduler subsystem over N models
                let mut opts = trident::coordinator::MultiServeCliOpts {
                    models: models.split(',').map(str::trim).map(String::from).collect(),
                    json,
                    ..trident::coordinator::MultiServeCliOpts::default()
                };
                opts.weights = parse_num_list(flags.get("weights"), "weights", 1u64);
                opts.priorities = parse_num_list(flags.get("priorities"), "priorities", 0u8);
                opts.deadline_ms = flags.get("deadline-ms").and_then(|v| v.parse().ok());
                opts.cap = flags.get("cap").and_then(|v| v.parse().ok());
                if let Some(q) = flags.get("queries").and_then(|v| v.parse().ok()) {
                    opts.queries = q;
                }
                opts.coalesce = flags.get("coalesce").and_then(|v| v.parse().ok());
                if let Some(l) = flags.get("low-water").and_then(|v| v.parse().ok()) {
                    opts.low_water = l;
                }
                if let Some(h) = flags.get("high-water").and_then(|v| v.parse().ok()) {
                    opts.high_water = h;
                }
                opts.containment = flags.get("containment").map(String::as_str) == Some("true");
                // bare `--trace` (no path) defaults to trace.jsonl
                opts.trace = flags.get("trace").map(|v| {
                    if v == "true" { "trace.jsonl".to_string() } else { v.clone() }
                });
                trident::coordinator::serve_tenants_cli(opts);
            } else {
                let mut opts = trident::coordinator::ServeCliOpts::default();
                if let Some(q) = flags.get("queries").and_then(|v| v.parse().ok()) {
                    opts.queries = q;
                }
                opts.coalesce = flags.get("coalesce").and_then(|v| v.parse().ok());
                if let Some(m) = flags.get("mode") {
                    opts.mode = m.clone();
                }
                if let Some(l) = flags.get("low-water").and_then(|v| v.parse().ok()) {
                    opts.low_water = l;
                }
                if let Some(h) = flags.get("high-water").and_then(|v| v.parse().ok()) {
                    opts.high_water = h;
                }
                opts.relu = flags.get("relu").map(String::as_str) == Some("true");
                trident::coordinator::serve_cli(opts);
                if json {
                    match trident::bench::write_serving_bench_json("BENCH_serving.json") {
                        Ok(_) => println!("wrote BENCH_serving.json"),
                        Err(e) => println!("could not write BENCH_serving.json: {e}"),
                    }
                }
            }
        }
        "metrics" => {
            trident::coordinator::metrics_cli();
        }
        _ => {
            println!(
                "trident — 4PC privacy-preserving ML (NDSS'20 reproduction)\n\
                 commands: quickstart | train | predict | tables | serve | metrics\n\
                 serve --models m1,m2 runs the multi-tenant scheduler; see README.md"
            );
        }
    }
}
