//! Trident CLI — leader entrypoint.
//!
//! ```text
//! trident quickstart                   # share → multiply → reconstruct demo
//! trident train   [--model nn|cnn|linreg|logreg] [--iters N] [--batch B] [--features D]
//! trident train   --epochs N [--model linreg|logreg|nn] [--batch B]
//!                 [--features D] [--ckpt-every K] [--lr-pow P]
//!                                      # scheduled training: the job runs
//!                                      # through the same registry/queue/
//!                                      # planner as serving (one wave per
//!                                      # epoch, per-epoch keyed pools,
//!                                      # checkpointed shares)
//! trident predict [--model ...] [--batch B]
//! trident tables  [table1 ... fig20 serve serve-tenants] [--json]
//!                                      # regenerate the paper's evaluation
//! trident serve   [--queries N] [--coalesce C] [--mode inline|scalar|keyed]
//!                 [--low-water L] [--high-water H] [--relu] [--json]
//!                                      # batched prediction serving demo
//! trident serve   --models m1,m2 [--weights 2,1] [--priorities 0,1]
//!                 [--deadline-ms D] [--cap N] [--queries N] [--coalesce C]
//!                 [--low-water L] [--high-water H] [--containment]
//!                 [--failover god|none] [--json] [--trace out.jsonl]
//!                 [--train [linreg|logreg|nn]] [--epochs N] [--batch B]
//!                                      # --train admits a scheduled
//!                                      # training job next to the
//!                                      # latency-sensitive tenants
//!                                      # multi-tenant scheduler demo;
//!                                      # --containment injects a mid-serve
//!                                      # tamper fault and quarantines the
//!                                      # poisoned tenant instead of dying;
//!                                      # --failover god degrades the
//!                                      # quarantined tenant to the Tetrad
//!                                      # GOD backend and rehabilitates it
//!                                      # after clean failover waves;
//!                                      # --trace writes the four-party
//!                                      # event stream as JSONL
//! trident metrics                      # Prometheus-style text snapshot of
//!                                      # the traced demo serving run
//! ```
//!
//! `--json` (serve / tables) additionally writes the machine-readable
//! serving benchmark to `BENCH_serving.json` at the repo root.

use std::collections::HashMap;

/// Parse a comma-separated numeric flag **positionally**: an unparsable
/// entry keeps its slot (with `default` and a warning) instead of being
/// dropped, so later values never shift onto the wrong model.
fn parse_num_list<T>(raw: Option<&String>, key: &str, default: T) -> Vec<T>
where
    T: std::str::FromStr + Copy + std::fmt::Display,
{
    match raw {
        None => Vec::new(),
        Some(v) => v
            .split(',')
            .enumerate()
            .map(|(i, tok)| {
                tok.trim().parse().unwrap_or_else(|_| {
                    println!("--{key} entry {i} ({tok:?}) is not a number; using {default}");
                    default
                })
            })
            .collect(),
    }
}

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    let cmd = pos.first().map(String::as_str).unwrap_or("help");
    let pjrt = trident::runtime::pjrt::init_default();

    match cmd {
        "quickstart" => {
            trident::coordinator::demo_quickstart();
        }
        "train" => {
            if let Some(epochs) = flags.get("epochs").and_then(|v| v.parse().ok()) {
                // scheduled-workload path: the job runs through the same
                // registry/queue/planner as serving
                let job = trident::coordinator::TrainJobOpts {
                    model: flags.get("model").cloned().unwrap_or_else(|| "linreg".into()),
                    epochs,
                    batch: flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(16),
                    features: flags.get("features").and_then(|v| v.parse().ok()).unwrap_or(8),
                    checkpoint_every: flags
                        .get("ckpt-every")
                        .and_then(|v| v.parse().ok())
                        .unwrap_or(0),
                    lr_pow: flags.get("lr-pow").and_then(|v| v.parse().ok()).unwrap_or(4),
                };
                trident::coordinator::train_workload_cli(
                    trident::coordinator::ServeConfig::new().train(job),
                );
            } else {
                let model = flags.get("model").map(String::as_str).unwrap_or("nn");
                let iters: usize =
                    flags.get("iters").and_then(|v| v.parse().ok()).unwrap_or(10);
                let batch: usize =
                    flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(128);
                let d: usize =
                    flags.get("features").and_then(|v| v.parse().ok()).unwrap_or(784);
                trident::coordinator::train_cli(model, iters, batch, d);
            }
        }
        "predict" => {
            let model = flags.get("model").map(String::as_str).unwrap_or("nn");
            let batch: usize = flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(100);
            trident::coordinator::predict_cli(model, batch);
        }
        "tables" => {
            println!("pjrt: {}", if pjrt { "enabled" } else { "native fallback" });
            let filter: Vec<String> = pos[1..].to_vec();
            print!("{}", trident::bench::run_tables(&filter));
            if flags.get("json").map(String::as_str) == Some("true") {
                match trident::bench::write_serving_bench_json("BENCH_serving.json") {
                    Ok(_) => println!("wrote BENCH_serving.json"),
                    Err(e) => println!("could not write BENCH_serving.json: {e}"),
                }
            }
        }
        "serve" => {
            let json = flags.get("json").map(String::as_str) == Some("true");
            // `--train` mixes a scheduled training job into the cluster
            // (bare flag = linreg; a value selects the model kind)
            let train_job = flags.get("train").map(|v| trident::coordinator::TrainJobOpts {
                model: if v == "true" { "linreg".into() } else { v.clone() },
                epochs: flags.get("epochs").and_then(|x| x.parse().ok()).unwrap_or(6),
                batch: flags.get("batch").and_then(|x| x.parse().ok()).unwrap_or(16),
                features: flags.get("features").and_then(|x| x.parse().ok()).unwrap_or(8),
                checkpoint_every: flags
                    .get("ckpt-every")
                    .and_then(|x| x.parse().ok())
                    .unwrap_or(0),
                lr_pow: flags.get("lr-pow").and_then(|x| x.parse().ok()).unwrap_or(4),
            });
            if flags.contains_key("models") || train_job.is_some() {
                // scheduler path: the subsystem over N models (+ the job)
                let models: Vec<String> = flags
                    .get("models")
                    .map(|m| m.split(',').map(str::trim).map(String::from).collect())
                    .unwrap_or_default();
                let mut opts = trident::coordinator::ServeConfig::tenants(models)
                    .weights(parse_num_list(flags.get("weights"), "weights", 1u64))
                    .priorities(parse_num_list(flags.get("priorities"), "priorities", 0u8))
                    .deadline_ms(flags.get("deadline-ms").and_then(|v| v.parse().ok()))
                    .cap(flags.get("cap").and_then(|v| v.parse().ok()))
                    .containment(
                        flags.get("containment").map(String::as_str) == Some("true"),
                    )
                    .failover(flags.get("failover").cloned())
                    .json(json)
                    // bare `--trace` (no path) defaults to trace.jsonl
                    .trace(flags.get("trace").map(|v| {
                        if v == "true" { "trace.jsonl".to_string() } else { v.clone() }
                    }));
                if let Some(q) = flags.get("queries").and_then(|v| v.parse().ok()) {
                    opts = opts.queries(q);
                }
                if let Some(c) = flags.get("coalesce").and_then(|v| v.parse().ok()) {
                    opts = opts.coalesce(c);
                }
                let lw = flags
                    .get("low-water")
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(opts.low_water);
                let hw = flags
                    .get("high-water")
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(opts.high_water);
                opts = opts.water(lw, hw);
                if let Some(job) = train_job {
                    opts = opts.train(job);
                }
                trident::coordinator::serve_cli(opts);
            } else {
                let mut opts = trident::coordinator::ServeConfig::new();
                if let Some(q) = flags.get("queries").and_then(|v| v.parse().ok()) {
                    opts = opts.queries(q);
                }
                if let Some(c) = flags.get("coalesce").and_then(|v| v.parse().ok()) {
                    opts = opts.coalesce(c);
                }
                if let Some(m) = flags.get("mode") {
                    opts = opts.mode(m);
                }
                let lw = flags
                    .get("low-water")
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(opts.low_water);
                let hw = flags
                    .get("high-water")
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(opts.high_water);
                opts = opts.water(lw, hw);
                opts = opts.relu(flags.get("relu").map(String::as_str) == Some("true"));
                trident::coordinator::serve_cli(opts);
                if json {
                    match trident::bench::write_serving_bench_json("BENCH_serving.json") {
                        Ok(_) => println!("wrote BENCH_serving.json"),
                        Err(e) => println!("could not write BENCH_serving.json: {e}"),
                    }
                }
            }
        }
        "metrics" => {
            trident::coordinator::metrics_cli();
        }
        _ => {
            println!(
                "trident — 4PC privacy-preserving ML (NDSS'20 reproduction)\n\
                 commands: quickstart | train | predict | tables | serve | metrics\n\
                 serve --models m1,m2 runs the multi-tenant scheduler; see README.md"
            );
        }
    }
}
