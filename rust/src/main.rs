//! Trident CLI — leader entrypoint.
//!
//! ```text
//! trident quickstart                   # share → multiply → reconstruct demo
//! trident train   [--model nn|cnn|linreg|logreg] [--iters N] [--batch B] [--features D]
//! trident predict [--model ...] [--batch B]
//! trident tables  [table1 ... fig20]   # regenerate the paper's evaluation
//! trident serve   [--queries N] [--coalesce C] [--mode inline|scalar|keyed]
//!                 [--low-water L] [--high-water H] [--relu]
//!                                      # batched prediction serving demo
//! ```

use std::collections::HashMap;

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    let cmd = pos.first().map(String::as_str).unwrap_or("help");
    let pjrt = trident::runtime::pjrt::init_default();

    match cmd {
        "quickstart" => {
            trident::coordinator::demo_quickstart();
        }
        "train" => {
            let model = flags.get("model").map(String::as_str).unwrap_or("nn");
            let iters: usize = flags.get("iters").and_then(|v| v.parse().ok()).unwrap_or(10);
            let batch: usize = flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(128);
            let d: usize = flags.get("features").and_then(|v| v.parse().ok()).unwrap_or(784);
            trident::coordinator::train_cli(model, iters, batch, d);
        }
        "predict" => {
            let model = flags.get("model").map(String::as_str).unwrap_or("nn");
            let batch: usize = flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(100);
            trident::coordinator::predict_cli(model, batch);
        }
        "tables" => {
            println!("pjrt: {}", if pjrt { "enabled" } else { "native fallback" });
            let filter: Vec<String> = pos[1..].to_vec();
            print!("{}", trident::bench::run_tables(&filter));
        }
        "serve" => {
            let mut opts = trident::coordinator::ServeCliOpts::default();
            if let Some(q) = flags.get("queries").and_then(|v| v.parse().ok()) {
                opts.queries = q;
            }
            opts.coalesce = flags.get("coalesce").and_then(|v| v.parse().ok());
            if let Some(m) = flags.get("mode") {
                opts.mode = m.clone();
            }
            if let Some(l) = flags.get("low-water").and_then(|v| v.parse().ok()) {
                opts.low_water = l;
            }
            if let Some(h) = flags.get("high-water").and_then(|v| v.parse().ok()) {
                opts.high_water = h;
            }
            opts.relu = flags.get("relu").map(String::as_str) == Some("true");
            trident::coordinator::serve_cli(opts);
        }
        _ => {
            println!(
                "trident — 4PC privacy-preserving ML (NDSS'20 reproduction)\n\
                 commands: quickstart | train | predict | tables | serve\n\
                 see README.md"
            );
        }
    }
}
