//! Gordon et al. 4PC baseline (ASIACRYPT'18, "Secure computation with low
//! communication from cross-checking") — the construction Trident §III
//! improves on:
//!
//! * online multiplication costs **4** ring elements (Trident: 3);
//! * **all four parties** are active throughout the online phase (Trident:
//!   P0 idle) — the basis of the Table XI per-party-runtime / monetary-cost
//!   comparison.

use crate::gc::circuit::Circuit;
use crate::net::NetProfile;

use super::PhaseCost;

/// Per-party online runtime for evaluating a boolean circuit, Gordon-style:
/// every AND layer is a 4-element exchange among all four parties.
pub fn circuit_party_times(c: &Circuit, profile: &NetProfile) -> [f64; 4] {
    let rounds = c.and_depth() as u64;
    let ands = c.and_count() as u64;
    // 4 single-bit elements per AND spread over the parties; each party both
    // sends and receives every round.
    let bits_per_party = ands; // 1 bit per AND per party on average
    let mut times = [0.0f64; 4];
    for (i, t) in times.iter_mut().enumerate() {
        // worst one-way latency this party sees
        let lat = profile.rtt[i].iter().cloned().fold(0.0, f64::max) / 2.0;
        *t = rounds as f64 * lat + bits_per_party as f64 / profile.bandwidth_bps;
    }
    times
}

/// Trident's per-party online times for the same circuit: the boolean-world
/// evaluation runs among P1–P3 only (3 elements per AND), P0 idle.
pub fn trident_circuit_party_times(c: &Circuit, profile: &NetProfile) -> [f64; 4] {
    let rounds = c.and_depth() as u64;
    let ands = c.and_count() as u64;
    let mut times = [0.0f64; 4];
    for (i, t) in times.iter_mut().enumerate().skip(1) {
        let lat = profile.rtt[i]
            .iter()
            .enumerate()
            .filter(|(j, _)| *j != 0) // P0 not involved
            .map(|(_, v)| *v)
            .fold(0.0, f64::max)
            / 2.0;
        *t = rounds as f64 * lat + ands as f64 / profile.bandwidth_bps;
    }
    times
}

/// Online multiplication cost (per gate).
pub fn mult_online() -> PhaseCost {
    PhaseCost { rounds: 1, bits: 4 * 64, compute: 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::circuit::aes_shaped;

    #[test]
    fn p0_idle_only_in_trident() {
        let c = aes_shaped();
        let wan = NetProfile::wan();
        let gordon = circuit_party_times(&c, &wan);
        let ours = trident_circuit_party_times(&c, &wan);
        assert!(gordon[0] > 0.0, "Gordon keeps P0 busy");
        assert_eq!(ours[0], 0.0, "Trident's P0 idle online");
        // total monetary cost must favour Trident (Table XI shape)
        let g_total: f64 = gordon.iter().sum();
        let t_total: f64 = ours.iter().sum();
        assert!(t_total < g_total, "total {t_total} vs gordon {g_total}");
    }

    #[test]
    fn mult_is_4_elements() {
        assert_eq!(mult_online().bits, 4 * 64);
    }
}
