//! Comparison baselines: ABY3 (3PC, Mohassel–Rindal CCS'18) and the 4PC of
//! Gordon et al. (ASIACRYPT'18).
//!
//! Two layers of fidelity (DESIGN.md §3):
//! * [`aby3::rss`] — a **functional** semi-honest replicated-secret-sharing
//!   engine (sharing, linearity, multiplication with resharing,
//!   reconstruction) validating the baseline's semantics;
//! * [`aby3::cost`] / [`gordon`] — **cost models** charging exactly the
//!   per-operation rounds/bits the paper's own Tables II/IX/X attribute to
//!   each baseline, evaluated under the same network profiles as the
//!   measured Trident runs. This is the paper's own comparison methodology
//!   (they re-implemented ABY3 and count the same formulas).

pub mod aby3;
pub mod gordon;

/// Time model shared by the analytic baselines: the same accounting the
/// metered runtime produces for Trident (DESIGN.md §7).
#[derive(Copy, Clone, Debug, Default)]
pub struct PhaseCost {
    pub rounds: u64,
    /// total bits on the wire
    pub bits: u64,
    /// local compute seconds (estimated)
    pub compute: f64,
}

impl PhaseCost {
    pub fn add(&mut self, o: PhaseCost) {
        self.rounds += o.rounds;
        self.bits += o.bits;
        self.compute += o.compute;
    }

    /// Latency under a network profile: rounds × max one-way latency +
    /// serialization + compute.
    pub fn latency(&self, profile: &crate::net::NetProfile) -> f64 {
        let max_rtt = profile
            .rtt
            .iter()
            .flatten()
            .cloned()
            .fold(0.0, f64::max);
        self.rounds as f64 * max_rtt / 2.0
            + self.bits as f64 / profile.bandwidth_bps
            + self.compute
    }
}
