//! ABY3 baseline (Mohassel–Rindal, CCS'18).
//!
//! [`rss`]: functional semi-honest 2-out-of-3 replicated secret sharing —
//! the substrate ABY3 builds on — validating share semantics, linearity,
//! multiplication-with-resharing, and reconstruction.
//!
//! [`cost`]: the analytic cost model used by every comparison table; the
//! constants are the paper's own ABY3 accounting (Tables I/II/IX/X):
//! malicious mult 9ℓ online (12ℓ with truncation), dot products scaling
//! linearly in the vector length, PPA-based bit extraction with `log ℓ`
//! online rounds, RCA-based truncation-pair generation with `2ℓ−2` offline
//! rounds.

use crate::ring::{Ring, Z64};

use super::PhaseCost;

/// Functional 2-out-of-3 replicated secret sharing (semi-honest ABY3 core).
pub mod rss {
    use super::*;
    use crate::crypto::Rng;

    /// Party `i` holds `(x_i, x_{i+1})` of `x = x_0 + x_1 + x_2`.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub struct Rep3<R>(pub R, pub R);

    /// Share a value into three replicated views.
    pub fn share<R: Ring>(v: R, rng: &mut Rng) -> [Rep3<R>; 3] {
        let x0: R = rng.gen();
        let x1: R = rng.gen();
        let x2 = v - x0 - x1;
        [Rep3(x0, x1), Rep3(x1, x2), Rep3(x2, x0)]
    }

    /// Reconstruct from all three views (cross-checking replicas).
    pub fn open<R: Ring>(shares: &[Rep3<R>; 3]) -> R {
        assert_eq!(shares[0].1, shares[1].0, "replica mismatch");
        assert_eq!(shares[1].1, shares[2].0, "replica mismatch");
        assert_eq!(shares[2].1, shares[0].0, "replica mismatch");
        shares[0].0 + shares[1].0 + shares[2].0
    }

    /// Local linear combination.
    pub fn add<R: Ring>(a: &[Rep3<R>; 3], b: &[Rep3<R>; 3]) -> [Rep3<R>; 3] {
        [
            Rep3(a[0].0 + b[0].0, a[0].1 + b[0].1),
            Rep3(a[1].0 + b[1].0, a[1].1 + b[1].1),
            Rep3(a[2].0 + b[2].0, a[2].1 + b[2].1),
        ]
    }

    /// Semi-honest multiplication: each party computes its cross-term
    /// `z_i = x_i·y_i + x_i·y_{i+1} + x_{i+1}·y_i (+ α_i)` and sends `z_i`
    /// to party `i−1` (one element per party — the "3 ring elements /
    /// 1 round" semi-honest cost). `alphas` is a fresh zero-sharing.
    pub fn mult<R: Ring>(
        x: &[Rep3<R>; 3],
        y: &[Rep3<R>; 3],
        alphas: [R; 3],
    ) -> [Rep3<R>; 3] {
        debug_assert_eq!(alphas[0] + alphas[1] + alphas[2], R::ZERO);
        let z: Vec<R> = (0..3)
            .map(|i| x[i].0 * y[i].0 + x[i].0 * y[i].1 + x[i].1 * y[i].0 + alphas[i])
            .collect();
        // resharing: party i-1 receives z_i → holds (z_{i-1}, z_i)
        [Rep3(z[0], z[1]), Rep3(z[1], z[2]), Rep3(z[2], z[0])]
    }

    /// Fresh zero sharing (PRF-derived in deployment).
    pub fn zero<R: Ring>(rng: &mut Rng) -> [R; 3] {
        let a: R = rng.gen();
        let b: R = rng.gen();
        [a, b, R::ZERO - a - b]
    }
}

/// Threat model for the cost model.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Security {
    SemiHonest,
    Malicious,
}

/// ABY3 per-operation cost model (`ℓ = 64`).
#[derive(Copy, Clone, Debug)]
pub struct Aby3Cost {
    pub sec: Security,
}

const L: u64 = 64;
const LOG_L: u64 = 6;
/// ns per u64 multiply-accumulate in the local compute estimate (matches the
/// native gemm's measured throughput on this image; see EXPERIMENTS.md §Perf).
pub const MAC_NS: f64 = 1.2e-9;

impl Aby3Cost {
    pub fn new(sec: Security) -> Aby3Cost {
        Aby3Cost { sec }
    }

    /// Dot product of length `d` with truncation, online phase
    /// (§I/§VI-A.a: "3 ring elements as opposed to 9d", truncation 12 vs 3).
    pub fn dotp_tr_online(&self, d: u64) -> PhaseCost {
        match self.sec {
            Security::Malicious => PhaseCost {
                rounds: 1,
                bits: (9 * d + 12) * L,
                compute: 3.0 * d as f64 * MAC_NS,
            },
            Security::SemiHonest => PhaseCost {
                rounds: 1,
                bits: 3 * L + 3 * L, // mult + share-truncation pair use
                compute: 3.0 * d as f64 * MAC_NS,
            },
        }
    }

    /// Matrix product (a×b ∘ b×c) with truncation, online.
    pub fn matmul_tr_online(&self, a: u64, b: u64, c: u64) -> PhaseCost {
        let per = self.dotp_tr_online(b);
        PhaseCost {
            rounds: per.rounds,
            bits: per.bits * a * c,
            compute: per.compute * (a * c) as f64,
        }
    }

    /// Offline truncation-pair generation (Table X: `2ℓ−2` rounds RCA,
    /// `96ℓ−42d−84` bits per pair for the malicious case).
    pub fn trunc_offline(&self, pairs: u64) -> PhaseCost {
        match self.sec {
            Security::Malicious => PhaseCost {
                rounds: 2 * L - 2,
                bits: (96 * L) * pairs,
                compute: 0.0,
            },
            Security::SemiHonest => PhaseCost { rounds: 2 * L - 2, bits: 32 * L * pairs, compute: 0.0 },
        }
    }

    /// ReLU online (Table II: `3 + log ℓ` rounds, 45ℓ bits malicious).
    pub fn relu_online(&self, n: u64) -> PhaseCost {
        let bits = match self.sec {
            Security::Malicious => 45 * L,
            Security::SemiHonest => 15 * L,
        };
        PhaseCost { rounds: 3 + LOG_L, bits: bits * n, compute: 0.0 }
    }

    /// Sigmoid online (Table II: `4 + log ℓ` rounds, 81ℓ+9 bits malicious).
    pub fn sigmoid_online(&self, n: u64) -> PhaseCost {
        let bits = match self.sec {
            Security::Malicious => 81 * L + 9,
            Security::SemiHonest => 27 * L + 3,
        };
        PhaseCost { rounds: 4 + LOG_L, bits: bits * n, compute: 0.0 }
    }

    /// Linear-regression training iteration, online (forward + backward).
    pub fn linreg_iter_online(&self, d: u64, batch: u64) -> PhaseCost {
        let mut c = self.matmul_tr_online(batch, d, 1);
        c.add(self.matmul_tr_online(d, batch, 1));
        c.rounds = 2;
        c
    }

    /// Logistic-regression iteration, online.
    pub fn logreg_iter_online(&self, d: u64, batch: u64) -> PhaseCost {
        let mut c = self.linreg_iter_online(d, batch);
        let s = self.sigmoid_online(batch);
        c.rounds += s.rounds;
        c.bits += s.bits;
        c
    }

    /// NN iteration, online, for layer widths `layers` (e.g. 784-128-128-10).
    pub fn nn_iter_online(&self, layers: &[u64], batch: u64) -> PhaseCost {
        let mut total = PhaseCost::default();
        // forward: matmul + relu per hidden layer
        for w in layers.windows(2) {
            let mm = self.matmul_tr_online(batch, w[0], w[1]);
            total.bits += mm.bits;
            total.compute += mm.compute;
            total.rounds += mm.rounds;
        }
        for w in &layers[1..layers.len() - 1] {
            let r = self.relu_online(batch * w);
            total.bits += r.bits;
            total.rounds += r.rounds;
        }
        // backward: error backprop matmuls + relu-derivative gates + updates
        for i in (0..layers.len() - 1).rev() {
            let upd = self.matmul_tr_online(layers[i], batch, layers[i + 1]);
            total.bits += upd.bits;
            total.compute += upd.compute;
            total.rounds += 1;
            if i > 0 {
                let back = self.matmul_tr_online(batch, layers[i + 1], layers[i]);
                total.bits += back.bits;
                total.compute += back.compute;
                // drelu gating ≈ a mult per element
                total.bits += 9 * L * batch * layers[i];
                total.rounds += 2;
            }
        }
        total
    }

    /// Prediction (forward only) online cost.
    pub fn predict_online(&self, layers: &[u64], batch: u64, relu_hidden: bool) -> PhaseCost {
        let mut total = PhaseCost::default();
        for w in layers.windows(2) {
            let mm = self.matmul_tr_online(batch, w[0], w[1]);
            total.add(mm);
        }
        if relu_hidden {
            for w in &layers[1..layers.len() - 1] {
                total.add(self.relu_online(batch * w));
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;
    use crate::net::NetProfile;

    #[test]
    fn rss_share_open_roundtrip() {
        let mut rng = Rng::seeded(400);
        for _ in 0..20 {
            let v: Z64 = rng.gen();
            assert_eq!(rss::open(&rss::share(v, &mut rng)), v);
        }
    }

    #[test]
    fn rss_mult_correct() {
        let mut rng = Rng::seeded(401);
        for _ in 0..20 {
            let a: Z64 = rng.gen();
            let b: Z64 = rng.gen();
            let sa = rss::share(a, &mut rng);
            let sb = rss::share(b, &mut rng);
            let z = rss::mult(&sa, &sb, rss::zero(&mut rng));
            assert_eq!(rss::open(&z), a * b);
        }
    }

    #[test]
    fn rss_linear() {
        let mut rng = Rng::seeded(402);
        let a: Z64 = rng.gen();
        let b: Z64 = rng.gen();
        let sum = rss::add(&rss::share(a, &mut rng), &rss::share(b, &mut rng));
        assert_eq!(rss::open(&sum), a + b);
    }

    #[test]
    fn cost_model_dotp_scales_with_d_only_for_aby3() {
        let m = Aby3Cost::new(Security::Malicious);
        let c10 = m.dotp_tr_online(10);
        let c1000 = m.dotp_tr_online(1000);
        assert!(c1000.bits > 50 * c10.bits, "ABY3 dot product must scale with d");
    }

    #[test]
    fn trident_beats_aby3_on_paper_metrics() {
        // Table IV shape check: our measured linreg iteration vs the ABY3
        // model, LAN, d=100, B=128 — Trident must win by >10×
        let aby3 = Aby3Cost::new(Security::Malicious);
        let lan = NetProfile::lan();
        let aby3_lat = aby3.linreg_iter_online(100, 128).latency(&lan);
        // Trident: 2 rounds, 3(B+d)ℓ bits
        let ours = PhaseCost { rounds: 2, bits: 3 * (128 + 100) * 64, compute: 0.0 };
        let ours_lat = ours.latency(&lan);
        assert!(
            aby3_lat > 10.0 * ours_lat,
            "aby3 {aby3_lat} vs ours {ours_lat}"
        );
    }
}
