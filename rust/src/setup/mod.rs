//! Shared-key setup `F_setup` (Appendix A, Fig. 21) and zero-sharing `Π_Zero`
//! (Fig. 22).
//!
//! `F_setup` establishes, among the four parties:
//! * one key per **pair** `k_ij`,
//! * one key per **triple** `k_ijk` (equivalently: per excluded party),
//! * one key `k_P` shared by all.
//!
//! Every "parties in P \ {P_j} together sample …" step in the protocols is a
//! draw from the triple key that excludes `P_j`. Correctness of the
//! correlated draws relies on all holders of a key pulling the same number of
//! elements in the same order — [`KeyChain`] keeps a per-key monotone counter
//! and [`KeyChain::position`] lets tests assert the streams stayed in sync.

use crate::crypto::{Key, Prf, Rng};
use crate::net::{PartyId, ALL};
use crate::ring::Ring;

/// A key scope: who shares the key.
#[derive(Copy, Clone, PartialEq, Eq, Debug, Hash)]
pub enum Scope {
    /// `k_ij`, shared by the (unordered) pair.
    Pair(PartyId, PartyId),
    /// `k_ijk`, named by the single excluded party: `Excl(j)` is the key of
    /// `P \ {P_j}`.
    Excl(PartyId),
    /// `k_P`, shared by everyone.
    All,
}

impl Scope {
    /// Canonicalize pair ordering.
    fn canon(self) -> Scope {
        match self {
            Scope::Pair(a, b) if a.0 > b.0 => Scope::Pair(b, a),
            s => s,
        }
    }

    /// Does `p` hold this key?
    pub fn holds(self, p: PartyId) -> bool {
        match self.canon() {
            Scope::Pair(a, b) => p == a || p == b,
            Scope::Excl(j) => p != j,
            Scope::All => true,
        }
    }
}

/// All scopes in canonical enumeration order (used by setup to derive keys).
fn all_scopes() -> Vec<Scope> {
    let mut v = Vec::new();
    for i in 0..4u8 {
        for j in (i + 1)..4 {
            v.push(Scope::Pair(PartyId(i), PartyId(j)));
        }
    }
    for j in ALL {
        v.push(Scope::Excl(j));
    }
    v.push(Scope::All);
    v
}

/// One party's view of the established keys: a PRF per held scope.
pub struct KeyChain {
    pub id: PartyId,
    prfs: Vec<(Scope, Prf)>,
}

impl KeyChain {
    fn prf(&mut self, scope: Scope) -> &mut Prf {
        let scope = scope.canon();
        assert!(scope.holds(self.id), "{} does not hold {scope:?}", self.id);
        self.prfs
            .iter_mut()
            .find(|(s, _)| *s == scope)
            .map(|(_, p)| p)
            .expect("scope present")
    }

    /// Draw one ring element from the scope's shared stream.
    pub fn sample<R: Ring>(&mut self, scope: Scope) -> R {
        self.prf(scope).gen()
    }

    /// Draw a vector.
    pub fn sample_vec<R: Ring>(&mut self, scope: Scope, n: usize) -> Vec<R> {
        self.prf(scope).gen_vec(n)
    }

    /// Draw from the triple key excluding `j` ("parties in P\{P_j} sample").
    pub fn sample_excl<R: Ring>(&mut self, j: PartyId) -> R {
        self.sample(Scope::Excl(j))
    }

    pub fn sample_excl_vec<R: Ring>(&mut self, j: PartyId, n: usize) -> Vec<R> {
        self.sample_vec(Scope::Excl(j), n)
    }

    /// Draw from the all-party key `k_P`.
    pub fn sample_all<R: Ring>(&mut self) -> R {
        self.sample(Scope::All)
    }

    /// Draw from the pairwise key with `other`.
    pub fn sample_pair<R: Ring>(&mut self, other: PartyId) -> R {
        self.sample(Scope::Pair(self.id, other))
    }

    pub fn sample_pair_vec<R: Ring>(&mut self, other: PartyId, n: usize) -> Vec<R> {
        self.sample_vec(Scope::Pair(self.id, other), n)
    }

    /// Draw a κ-bit key (e.g. garbled-circuit offset R) from a scope.
    pub fn sample_key(&mut self, scope: Scope) -> Key {
        self.prf(scope).gen_key()
    }

    /// Stream position of a scope (sync sanity checks).
    pub fn position(&mut self, scope: Scope) -> u128 {
        self.prf(scope).position()
    }
}

/// Trusted-dealer instantiation of `F_setup`: derive all scope keys from a
/// master seed and hand each party its [`KeyChain`].
///
/// In deployment this is a one-time interactive setup (Fig. 21); the
/// simulation derives it deterministically so experiments are reproducible.
pub fn setup_keys(master_seed: u64) -> [KeyChain; 4] {
    let mut rng = Rng::seeded(master_seed ^ SETUP_DOMAIN);
    let scoped_keys: Vec<(Scope, Key)> = all_scopes().into_iter().map(|s| (s, rng.gen_key())).collect();
    let mk = |id: PartyId| KeyChain {
        id,
        prfs: scoped_keys
            .iter()
            .filter(|(s, _)| s.holds(id))
            .map(|(s, k)| (*s, Prf::new(*k)))
            .collect(),
    };
    [mk(ALL[0]), mk(ALL[1]), mk(ALL[2]), mk(ALL[3])]
}

/// Domain separator ("trident\0") so setup seeds don't collide with other
/// seeded RNG uses.
const SETUP_DOMAIN: u64 = 0x7472_6964_656e_7400;

/// Π_Zero output: the party's view of a fresh ⟨·⟩-sharing of zero.
///
/// `A + B + Γ = 0`, with `A` held by `{P0,P1}`, `B` by `{P0,P2}`,
/// `Γ` by `{P0,P3}` (Fig. 22).
#[derive(Clone, Debug, Default)]
pub struct ZeroShare<R> {
    pub a: Option<R>,
    pub b: Option<R>,
    pub gamma: Option<R>,
}

/// Non-interactive zero-sharing (Fig. 22). **Every** holder of a key draws
/// from it (even when the drawn value does not enter its own share) so all
/// streams stay aligned.
pub fn zero_share<R: Ring>(keys: &mut KeyChain) -> ZeroShare<R> {
    let id = keys.id;
    // k1 = excl(P2), k2 = excl(P3), k3 = excl(P1) per Fig. 22's naming.
    let f_k1: Option<R> = Scope::Excl(crate::net::P2).holds(id).then(|| keys.sample_excl(crate::net::P2));
    let f_k2: Option<R> = Scope::Excl(crate::net::P3).holds(id).then(|| keys.sample_excl(crate::net::P3));
    let f_k3: Option<R> = Scope::Excl(crate::net::P1).holds(id).then(|| keys.sample_excl(crate::net::P1));

    let a = match (f_k2, f_k1) {
        (Some(x2), Some(x1)) => Some(x2 - x1),
        _ => None,
    };
    let b = match (f_k3, f_k2) {
        (Some(x3), Some(x2)) => Some(x3 - x2),
        _ => None,
    };
    let gamma = match (f_k1, f_k3) {
        (Some(x1), Some(x3)) => Some(x1 - x3),
        _ => None,
    };
    match id {
        crate::net::P0 => ZeroShare { a, b, gamma },
        crate::net::P1 => ZeroShare { a, b: None, gamma: None },
        crate::net::P2 => ZeroShare { a: None, b, gamma: None },
        crate::net::P3 => ZeroShare { a: None, b: None, gamma },
        _ => unreachable!("invalid party id"),
    }
}

/// Vector variant of [`zero_share`].
pub fn zero_share_vec<R: Ring>(keys: &mut KeyChain, n: usize) -> Vec<ZeroShare<R>> {
    (0..n).map(|_| zero_share(keys)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{P0, P1, P2, P3};
    use crate::ring::{Bit, Z64};

    #[test]
    fn scopes_hold_correct_parties() {
        assert!(Scope::Excl(P2).holds(P0));
        assert!(!Scope::Excl(P2).holds(P2));
        assert!(Scope::Pair(P1, P3).holds(P3));
        assert!(!Scope::Pair(P1, P3).holds(P2));
        assert!(Scope::All.holds(P0));
        // pair canonicalization
        assert!(Scope::Pair(P3, P1).holds(P1));
    }

    #[test]
    fn correlated_draws_agree() {
        let [mut k0, mut k1, mut k2, mut k3] = setup_keys(7);
        // excl(P2): P0, P1, P3 agree; P2 cannot draw
        let a: Z64 = k0.sample_excl(P2);
        let b: Z64 = k1.sample_excl(P2);
        let c: Z64 = k3.sample_excl(P2);
        assert_eq!(a, b);
        assert_eq!(b, c);
        // all-key agreement
        let w: Z64 = k0.sample_all();
        let x: Z64 = k1.sample_all();
        let y: Z64 = k2.sample_all();
        let z: Z64 = k3.sample_all();
        assert_eq!(w, x);
        assert_eq!(x, y);
        assert_eq!(y, z);
        // pairwise
        let p: Z64 = k1.sample_pair(P2);
        let q: Z64 = k2.sample_pair(P1);
        assert_eq!(p, q);
    }

    #[test]
    #[should_panic]
    fn non_holder_cannot_draw() {
        let [_, _, mut k2, _] = setup_keys(7);
        let _: Z64 = k2.sample_excl(P2);
    }

    #[test]
    fn different_seeds_different_keys() {
        let [mut a0, ..] = setup_keys(1);
        let [mut b0, ..] = setup_keys(2);
        let x: Z64 = a0.sample_all();
        let y: Z64 = b0.sample_all();
        assert_ne!(x, y);
    }

    #[test]
    fn zero_shares_sum_to_zero() {
        let [mut k0, mut k1, mut k2, mut k3] = setup_keys(42);
        for _ in 0..50 {
            let z0 = zero_share::<Z64>(&mut k0);
            let z1 = zero_share::<Z64>(&mut k1);
            let z2 = zero_share::<Z64>(&mut k2);
            let z3 = zero_share::<Z64>(&mut k3);
            let a = z1.a.unwrap();
            let b = z2.b.unwrap();
            let g = z3.gamma.unwrap();
            assert_eq!(a + b + g, Z64(0));
            // P0 sees all three and they match
            assert_eq!(z0.a.unwrap(), a);
            assert_eq!(z0.b.unwrap(), b);
            assert_eq!(z0.gamma.unwrap(), g);
        }
    }

    #[test]
    fn zero_shares_boolean_world() {
        let [mut k0, mut k1, mut k2, mut k3] = setup_keys(43);
        for _ in 0..32 {
            let _ = zero_share::<Bit>(&mut k0);
            let z1 = zero_share::<Bit>(&mut k1);
            let z2 = zero_share::<Bit>(&mut k2);
            let z3 = zero_share::<Bit>(&mut k3);
            assert_eq!(z1.a.unwrap() + z2.b.unwrap() + z3.gamma.unwrap(), Bit(false));
        }
    }

    #[test]
    fn zero_shares_look_random() {
        let [_, mut k1, ..] = setup_keys(44);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..64 {
            seen.insert(zero_share::<Z64>(&mut k1).a.unwrap().0);
        }
        assert!(seen.len() > 60, "zero shares should be near-unique");
    }

    #[test]
    fn batched_and_per_element_draws_stay_in_lockstep() {
        // the pool-fill guard at the KeyChain level: one holder fills in
        // one gen_vec batch while the others draw per element — identical
        // values and identical stream positions, over Z64, Bit and a mixed
        // sequence (the keystream-batched PRF must be consumption-
        // equivalent to the per-element path at every party)
        let [mut k0, mut k1, _, mut k3] = setup_keys(46);
        let batched: Vec<Z64> = k0.sample_excl_vec(P2, 5);
        let scalar: Vec<Z64> = (0..5).map(|_| k1.sample_excl(P2)).collect();
        let scalar3: Vec<Z64> = (0..5).map(|_| k3.sample_excl(P2)).collect();
        assert_eq!(batched, scalar);
        assert_eq!(scalar, scalar3);

        let bb: Vec<Bit> = k0.sample_excl_vec(P2, 137);
        let sb: Vec<Bit> = (0..137).map(|_| k1.sample_excl(P2)).collect();
        assert_eq!(bb, sb);

        // mixed tail: a Z64 draw after the bit batch stays aligned too
        let z0: Z64 = k0.sample_excl(P2);
        let z1: Z64 = k1.sample_excl(P2);
        assert_eq!(z0, z1);
        assert_eq!(k0.position(Scope::Excl(P2)), k1.position(Scope::Excl(P2)));
        assert_eq!(k0.position(Scope::Excl(P2)), k3.position(Scope::Excl(P2)) + 2);
    }

    #[test]
    fn streams_stay_in_position_sync() {
        let [mut k0, mut k1, mut k2, mut k3] = setup_keys(45);
        for _ in 0..10 {
            let _ = zero_share::<Z64>(&mut k0);
            let _ = zero_share::<Z64>(&mut k1);
            let _ = zero_share::<Z64>(&mut k2);
            let _ = zero_share::<Z64>(&mut k3);
        }
        for j in [P1, P2, P3] {
            let mut positions = Vec::new();
            for k in [&mut k0, &mut k1, &mut k2, &mut k3] {
                if Scope::Excl(j).holds(k.id) {
                    positions.push(k.position(Scope::Excl(j)));
                }
            }
            assert!(positions.windows(2).all(|w| w[0] == w[1]), "desync on excl({j})");
        }
    }
}
