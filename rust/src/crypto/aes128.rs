//! In-crate AES-128 encryption (FIPS 197) — the PRF substrate.
//!
//! Replaces the `aes` crate (unavailable in the offline build image). Only
//! the encrypt direction is needed: the shared-key PRF and the fixed-key
//! garbling hash both use AES in counter / Davies–Meyer-style modes.
//!
//! The S-box is derived at first use from its algebraic definition
//! (GF(2^8) inversion + affine map) rather than transcribed, so it is
//! correct by construction. Plain table-lookup rounds — fast enough for the
//! in-process simulation; a deployment would use AES-NI.

use std::sync::OnceLock;

static SBOX: OnceLock<[u8; 256]> = OnceLock::new();

/// GF(2^8) multiply-by-x (the `xtime` of FIPS 197), modulo x^8+x^4+x^3+x+1.
#[inline]
fn xtime(a: u8) -> u8 {
    (a << 1) ^ (if a & 0x80 != 0 { 0x1b } else { 0 })
}

/// Build the S-box from log/antilog tables over generator 3.
fn build_sbox() -> [u8; 256] {
    let mut alog = [0u8; 256];
    let mut log = [0u8; 256];
    let mut x: u8 = 1;
    for i in 0..255 {
        alog[i] = x;
        log[x as usize] = i as u8;
        x = xtime(x) ^ x; // multiply by the generator 0x03
    }
    alog[255] = alog[0];
    let mut sbox = [0u8; 256];
    for (i, s) in sbox.iter_mut().enumerate() {
        let inv = if i == 0 { 0 } else { alog[(255 - log[i] as usize) % 255] };
        // affine transform: b ^ rot1(b) ^ rot2(b) ^ rot3(b) ^ rot4(b) ^ 0x63
        *s = inv
            ^ inv.rotate_left(1)
            ^ inv.rotate_left(2)
            ^ inv.rotate_left(3)
            ^ inv.rotate_left(4)
            ^ 0x63;
    }
    sbox
}

#[inline]
fn sbox() -> &'static [u8; 256] {
    SBOX.get_or_init(build_sbox)
}

/// An expanded AES-128 encryption key (11 round keys).
#[derive(Clone)]
pub struct Aes128 {
    /// Round keys, flat column-major bytes (index `4·col + row`), matching
    /// the state layout.
    rk: [[u8; 16]; 11],
}

impl Aes128 {
    pub fn new(key: [u8; 16]) -> Aes128 {
        let sb = sbox();
        // words as 4-byte columns
        let mut w = [[0u8; 4]; 44];
        for i in 0..4 {
            w[i].copy_from_slice(&key[4 * i..4 * i + 4]);
        }
        let mut rcon: u8 = 1;
        for i in 4..44 {
            let mut t = w[i - 1];
            if i % 4 == 0 {
                t.rotate_left(1); // RotWord
                for b in &mut t {
                    *b = sb[*b as usize]; // SubWord
                }
                t[0] ^= rcon;
                rcon = xtime(rcon);
            }
            for j in 0..4 {
                w[i][j] = w[i - 4][j] ^ t[j];
            }
        }
        let mut rk = [[0u8; 16]; 11];
        for (r, round_key) in rk.iter_mut().enumerate() {
            for c in 0..4 {
                round_key[4 * c..4 * c + 4].copy_from_slice(&w[4 * r + c]);
            }
        }
        Aes128 { rk }
    }

    /// Encrypt one 16-byte block.
    pub fn encrypt_block(&self, block: [u8; 16]) -> [u8; 16] {
        let sb = sbox();
        let mut s = block;
        add_round_key(&mut s, &self.rk[0]);
        for round in 1..10 {
            sub_bytes(&mut s, sb);
            shift_rows(&mut s);
            mix_columns(&mut s);
            add_round_key(&mut s, &self.rk[round]);
        }
        sub_bytes(&mut s, sb);
        shift_rows(&mut s);
        add_round_key(&mut s, &self.rk[10]);
        s
    }
}

#[inline]
fn add_round_key(s: &mut [u8; 16], rk: &[u8; 16]) {
    for i in 0..16 {
        s[i] ^= rk[i];
    }
}

#[inline]
fn sub_bytes(s: &mut [u8; 16], sb: &[u8; 256]) {
    for b in s.iter_mut() {
        *b = sb[*b as usize];
    }
}

/// Row `r` of the column-major state rotates left by `r`.
#[inline]
fn shift_rows(s: &mut [u8; 16]) {
    let old = *s;
    for r in 1..4 {
        for c in 0..4 {
            s[4 * c + r] = old[4 * ((c + r) % 4) + r];
        }
    }
}

#[inline]
fn mix_columns(s: &mut [u8; 16]) {
    for c in 0..4 {
        let a0 = s[4 * c];
        let a1 = s[4 * c + 1];
        let a2 = s[4 * c + 2];
        let a3 = s[4 * c + 3];
        s[4 * c] = xtime(a0) ^ (xtime(a1) ^ a1) ^ a2 ^ a3;
        s[4 * c + 1] = a0 ^ xtime(a1) ^ (xtime(a2) ^ a2) ^ a3;
        s[4 * c + 2] = a0 ^ a1 ^ xtime(a2) ^ (xtime(a3) ^ a3);
        s[4 * c + 3] = (xtime(a0) ^ a0) ^ a1 ^ a2 ^ xtime(a3);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sbox_known_entries() {
        let sb = sbox();
        assert_eq!(sb[0x00], 0x63);
        assert_eq!(sb[0x01], 0x7c);
        assert_eq!(sb[0x53], 0xed);
        assert_eq!(sb[0xff], 0x16);
    }

    #[test]
    fn fips197_appendix_b() {
        // FIPS 197 Appendix B: key 2b7e..., plaintext 3243f6a8885a308d313198a2e0370734
        let key = [
            0x2b, 0x7e, 0x15, 0x16, 0x28, 0xae, 0xd2, 0xa6, 0xab, 0xf7, 0x15, 0x88, 0x09, 0xcf,
            0x4f, 0x3c,
        ];
        let pt = [
            0x32, 0x43, 0xf6, 0xa8, 0x88, 0x5a, 0x30, 0x8d, 0x31, 0x31, 0x98, 0xa2, 0xe0, 0x37,
            0x07, 0x34,
        ];
        let want = [
            0x39, 0x25, 0x84, 0x1d, 0x02, 0xdc, 0x09, 0xfb, 0xdc, 0x11, 0x85, 0x97, 0x19, 0x6a,
            0x0b, 0x32,
        ];
        let aes = Aes128::new(key);
        assert_eq!(aes.encrypt_block(pt), want);
    }

    #[test]
    fn fips197_appendix_c1() {
        // FIPS 197 Appendix C.1: key 000102...0f, plaintext 00112233...ff
        let key: [u8; 16] = core::array::from_fn(|i| i as u8);
        let pt: [u8; 16] = core::array::from_fn(|i| (i * 0x11) as u8);
        let want = [
            0x69, 0xc4, 0xe0, 0xd8, 0x6a, 0x7b, 0x04, 0x30, 0xd8, 0xcd, 0xb7, 0x80, 0x70, 0xb4,
            0xc5, 0x5a,
        ];
        let aes = Aes128::new(key);
        assert_eq!(aes.encrypt_block(pt), want);
    }

    #[test]
    fn deterministic_and_key_sensitive() {
        let a = Aes128::new([7u8; 16]);
        let b = Aes128::new([7u8; 16]);
        let c = Aes128::new([8u8; 16]);
        let blk = [1u8; 16];
        assert_eq!(a.encrypt_block(blk), b.encrypt_block(blk));
        assert_ne!(a.encrypt_block(blk), c.encrypt_block(blk));
    }
}
