//! Cryptographic substrate (paper Appendix A).
//!
//! * [`Prf`] — the shared-key pseudo-random function `F : {0,1}^κ × {0,1}^κ → X`
//!   used by `F_setup`-established keys for non-interactive correlated
//!   randomness. Instantiated as fixed-key-free AES-128 over a counter, the
//!   standard choice in the high-throughput honest-majority line of work
//!   (Araki et al.) that Trident builds on.
//! * [`hash_digest`] / [`HashAcc`] — the collision-resistant hash `H()`
//!   (SHA-256, as in §VI) with an *accumulating* variant used to batch many
//!   consistency checks into a single digest exchange — this is the
//!   amortization every communication lemma in Appendices B–D relies on.
//! * [`Commitment`] — hash-based commitments for the garbled world's key
//!   delivery (`Π_Sh^G`, Fig. 6).
//! * [`Rng`] — a fast, seedable local RNG (xoshiro256**) for dealer/test
//!   randomness. NOT used for shared randomness (that is the PRF's job).

pub mod aes128;
pub mod sha256;

use aes128::Aes128;
use sha256::Sha256;

use crate::ring::Ring;

/// κ = 128-bit computational security parameter (paper §IV-A).
pub const KAPPA_BYTES: usize = 16;

/// Key type for PRFs and garbling: 128-bit.
pub type Key = [u8; 16];

/// 256-bit hash digest.
pub type Digest32 = [u8; 32];

/// AES-128-based PRF with a monotone counter, consumed as a **buffered
/// CTR keystream**.
///
/// Two parties holding the same key and drawing the same number of elements
/// in the same order obtain identical streams — the mechanism behind every
/// "parties in P \ {P_j} together sample λ_{v,j}" step.
///
/// ## Keystream consumption contract
///
/// The seed burned one full `encrypt_block` per drawn element — a single
/// [`crate::ring::Bit`] cost 16 keystream bytes. Elements now slice a
/// shared keystream instead, and every holder of a key consumes it the
/// same way, so the streams stay lockstep-deterministic:
///
/// * sub-byte rings (`Bit`) consume exactly `BITS` keystream **bits**
///   (LSB-first within each byte) — a `Bit` vector unpacks 128 elements
///   per AES block;
/// * byte-granular rings consume `WIRE_BYTES` bytes, little-endian (the
///   canonical [`Ring::from_wire`] decode) — `Z64` uses **both** 8-byte
///   lanes of a block; byte draws first round the cursor up to the next
///   byte boundary;
/// * κ-bit key draws ([`Prf::gen_key`]) consume 16 bytes.
///
/// `gen_vec(n)` is consumption-for-consumption identical to `n` scalar
/// `gen` calls (it only fills whole blocks in bulk), so batched pool fills
/// and per-element inline draws leave every party at the same
/// [`Prf::position`] — the lockstep-determinism guard the pool fills rely
/// on, pinned by the `keystream_*` tests below.
#[derive(Clone)]
pub struct Prf {
    cipher: Aes128,
    /// CTR block counter: keystream blocks generated so far.
    counter: u128,
    /// Current keystream block; valid from bit `used` onward.
    buf: [u8; 16],
    /// Bits of `buf` already consumed (128 ⇒ a fresh block is needed).
    used: usize,
    /// Reusable bulk-fill buffer: `gen_vec` slices elements out of it, so
    /// a large draw costs one resize instead of a per-element allocation.
    scratch: Vec<u8>,
}

impl std::fmt::Debug for Prf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Prf(ctr={}, used={})", self.counter, self.used)
    }
}

impl Prf {
    pub fn new(key: Key) -> Self {
        Prf {
            cipher: Aes128::new(key),
            counter: 0,
            buf: [0u8; 16],
            used: 128,
            scratch: Vec::new(),
        }
    }

    /// Encrypt the next counter block (the only place the counter moves).
    #[inline]
    fn next_keystream_block(&mut self) -> [u8; 16] {
        let block = self.cipher.encrypt_block(self.counter.to_le_bytes());
        self.counter += 1;
        block
    }

    #[inline]
    fn refill(&mut self) {
        self.buf = self.next_keystream_block();
        self.used = 0;
    }

    /// One keystream bit (LSB-first within each byte of the block).
    #[inline]
    fn take_bit(&mut self) -> bool {
        if self.used == 128 {
            self.refill();
        }
        let bit = (self.buf[self.used / 8] >> (self.used % 8)) & 1;
        self.used += 1;
        bit == 1
    }

    /// Fill `out` with keystream bytes: aligns to a byte boundary, drains
    /// the buffered partial block, then encrypts whole blocks straight into
    /// the destination (the bulk path `gen_vec` rides). An empty request
    /// consumes nothing — zero elements must leave the stream untouched so
    /// an empty bulk draw stays lockstep with "no draw at all" at peers.
    fn take_bytes(&mut self, out: &mut [u8]) {
        if out.is_empty() {
            return;
        }
        self.used = (self.used + 7) & !7;
        let mut filled = 0;
        while self.used < 128 && filled < out.len() {
            out[filled] = self.buf[self.used / 8];
            self.used += 8;
            filled += 1;
        }
        while out.len() - filled >= 16 {
            let block = self.next_keystream_block();
            out[filled..filled + 16].copy_from_slice(&block);
            filled += 16;
        }
        if filled < out.len() {
            self.refill();
            let tail = out.len() - filled;
            out[filled..].copy_from_slice(&self.buf[..tail]);
            self.used = 8 * tail;
        }
    }

    /// Next 16 keystream bytes (byte-aligned; spans blocks when the cursor
    /// is mid-block).
    #[inline]
    pub fn next_block(&mut self) -> [u8; 16] {
        let mut out = [0u8; 16];
        self.take_bytes(&mut out);
        out
    }

    /// One sub-byte element: exactly `R::BITS` keystream bits, LSB-first
    /// into the canonical one-byte wire encoding.
    #[inline]
    fn gen_sub_byte<R: Ring>(&mut self) -> R {
        let mut byte = 0u8;
        for k in 0..R::BITS {
            byte |= (self.take_bit() as u8) << k;
        }
        R::from_wire(&[byte]).expect("sub-byte ring decodes from one byte").0
    }

    /// Sample one ring element (see the consumption contract above).
    #[inline]
    pub fn gen<R: Ring>(&mut self) -> R {
        if R::BITS < 8 {
            self.gen_sub_byte()
        } else {
            debug_assert!(R::WIRE_BYTES <= 16, "ring element exceeds one block");
            let mut tmp = [0u8; 16];
            let nb = R::WIRE_BYTES;
            self.take_bytes(&mut tmp[..nb]);
            R::from_wire(&tmp[..nb]).expect("keystream bytes decode").0
        }
    }

    /// Sample `n` ring elements — consumption-identical to `n` [`Prf::gen`]
    /// calls, but whole blocks are filled in bulk and elements sliced out
    /// of the reusable buffer.
    pub fn gen_vec<R: Ring>(&mut self, n: usize) -> Vec<R> {
        if R::BITS < 8 {
            // 128 bits per block, unpacked straight from the buffer
            (0..n).map(|_| self.gen_sub_byte()).collect()
        } else {
            let nb = R::WIRE_BYTES;
            let mut scratch = std::mem::take(&mut self.scratch);
            scratch.clear();
            scratch.resize(n * nb, 0);
            self.take_bytes(&mut scratch);
            let out = scratch
                .chunks_exact(nb)
                .map(|c| R::from_wire(c).expect("keystream bytes decode").0)
                .collect();
            self.scratch = scratch;
            out
        }
    }

    /// Sample a κ-bit key (for garbled labels, offsets, …): 16 keystream
    /// bytes.
    #[inline]
    pub fn gen_key(&mut self) -> Key {
        self.next_block()
    }

    /// Number of keystream blocks generated so far — the synchronization
    /// sanity check. Identical draw sequences leave identical positions,
    /// whether drawn per element or via `gen_vec`.
    pub fn position(&self) -> u128 {
        self.counter
    }

    /// Exact keystream cursor in bits (finer-grained than [`Prf::position`];
    /// also equal across parties after identical draw sequences).
    pub fn stream_bits(&self) -> u128 {
        self.counter * 128 - (128 - self.used as u128)
    }
}

/// One-shot collision-resistant hash H(x).
pub fn hash_digest(data: &[u8]) -> Digest32 {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize().into()
}

/// Accumulating hash: absorb many values, emit one digest.
///
/// "the corresponding values can be appended and hashed, resulting in an
/// overall communication of only 3 ring elements" (§III-C) — protocols push
/// every to-be-verified value into one of these and exchange a single digest
/// at a flush point.
#[derive(Clone)]
pub struct HashAcc {
    h: Sha256,
    len: usize,
}

impl Default for HashAcc {
    fn default() -> Self {
        Self::new()
    }
}

impl HashAcc {
    pub fn new() -> Self {
        HashAcc { h: Sha256::new(), len: 0 }
    }

    pub fn absorb(&mut self, data: &[u8]) {
        // length-prefix every item so absorb("ab","c") != absorb("a","bc")
        self.h.update((data.len() as u64).to_le_bytes());
        self.h.update(data);
        self.len += 1;
    }

    pub fn absorb_ring<R: Ring>(&mut self, v: &R) {
        let mut buf = Vec::with_capacity(R::WIRE_BYTES);
        v.to_wire(&mut buf);
        self.absorb(&buf);
    }

    /// Number of absorbed items.
    pub fn items(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn finalize(self) -> Digest32 {
        self.h.finalize().into()
    }
}

/// Hash-based commitment `Com(m; r) = H(r ‖ m)` with 128-bit randomness.
///
/// Binding from collision resistance, hiding from the random prefix —
/// sufficient for the garbled-sharing key commitments of Fig. 6/8.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Commitment(pub Digest32);

impl Commitment {
    pub fn commit(msg: &[u8], rand: &Key) -> Commitment {
        let mut h = Sha256::new();
        h.update(rand);
        h.update(msg);
        Commitment(h.finalize().into())
    }

    /// Verify an opening (message + randomness).
    pub fn verify(&self, msg: &[u8], rand: &Key) -> bool {
        Commitment::commit(msg, rand) == *self
    }
}

/// xoshiro256** — fast local randomness for dealers, tests, and synthetic
/// data. Deterministic from a seed so every experiment is reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seeded(seed: u64) -> Rng {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// OS-seeded (non-deterministic) RNG.
    pub fn from_entropy() -> Rng {
        use std::time::{SystemTime, UNIX_EPOCH};
        let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap();
        Rng::seeded(t.as_nanos() as u64 ^ (std::process::id() as u64) << 32)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn gen<R: Ring>(&mut self) -> R {
        let mut block = [0u8; 16];
        block[..8].copy_from_slice(&self.next_u64().to_le_bytes());
        block[8..].copy_from_slice(&self.next_u64().to_le_bytes());
        R::from_block(&block)
    }

    pub fn gen_vec<R: Ring>(&mut self, n: usize) -> Vec<R> {
        (0..n).map(|_| self.gen()).collect()
    }

    pub fn gen_key(&mut self) -> Key {
        let mut k = [0u8; 16];
        k[..8].copy_from_slice(&self.next_u64().to_le_bytes());
        k[8..].copy_from_slice(&self.next_u64().to_le_bytes());
        k
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller (for synthetic datasets).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::{Bit, Z64};

    #[test]
    fn prf_deterministic_and_synced() {
        let k = [7u8; 16];
        let mut a = Prf::new(k);
        let mut b = Prf::new(k);
        for _ in 0..100 {
            assert_eq!(a.gen::<Z64>(), b.gen::<Z64>());
        }
        assert_eq!(a.position(), b.position());
    }

    #[test]
    fn prf_differs_across_keys() {
        let mut a = Prf::new([1u8; 16]);
        let mut b = Prf::new([2u8; 16]);
        let va: Vec<Z64> = a.gen_vec(8);
        let vb: Vec<Z64> = b.gen_vec(8);
        assert_ne!(va, vb);
    }

    #[test]
    fn prf_stream_not_constant() {
        let mut a = Prf::new([9u8; 16]);
        let v: Vec<Z64> = a.gen_vec(16);
        assert!(v.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn keystream_batched_equals_per_element_z64() {
        let k = [3u8; 16];
        let mut batched = Prf::new(k);
        let mut scalar = Prf::new(k);
        let vb: Vec<Z64> = batched.gen_vec(7);
        let vs: Vec<Z64> = (0..7).map(|_| scalar.gen()).collect();
        assert_eq!(vb, vs, "gen_vec must slice the same keystream as n× gen");
        assert_eq!(batched.position(), scalar.position());
        assert_eq!(batched.stream_bits(), scalar.stream_bits());
        // Z64 consumes both 8-byte lanes: 7 elements = 56 bytes = 4 blocks
        assert_eq!(batched.position(), 4);
    }

    #[test]
    fn keystream_batched_equals_per_element_bits() {
        let k = [4u8; 16];
        let mut batched = Prf::new(k);
        let mut scalar = Prf::new(k);
        let vb: Vec<Bit> = batched.gen_vec(300);
        let vs: Vec<Bit> = (0..300).map(|_| scalar.gen()).collect();
        assert_eq!(vb, vs);
        assert_eq!(batched.position(), scalar.position());
        assert_eq!(batched.stream_bits(), scalar.stream_bits());
        // bit vectors unpack 128 bits per block: 300 bits = 3 blocks
        assert_eq!(batched.position(), 3);
        assert!(vb.iter().any(|b| b.0) && vb.iter().any(|b| !b.0));
    }

    #[test]
    fn keystream_mixed_sequences_stay_in_lockstep() {
        // the pool-fill guard: a party that fills in batches and a party
        // that draws per element must agree on every value AND position,
        // over a mixed Z64 / Bit / key sequence
        let k = [5u8; 16];
        let mut a = Prf::new(k);
        let mut b = Prf::new(k);
        let a1: Vec<Z64> = a.gen_vec(3);
        let a2: Vec<Bit> = a.gen_vec(130);
        let a3: Z64 = a.gen();
        let a4 = a.gen_key();
        let b1: Vec<Z64> = (0..3).map(|_| b.gen()).collect();
        let b2: Vec<Bit> = (0..130).map(|_| b.gen()).collect();
        let b3: Z64 = b.gen();
        let b4 = b.gen_key();
        assert_eq!((a1, a2, a3, a4), (b1, b2, b3, b4));
        assert_eq!(a.position(), b.position());
        assert_eq!(a.stream_bits(), b.stream_bits());
        // and the streams keep agreeing afterwards
        assert_eq!(a.gen::<Z64>(), b.gen::<Z64>());
    }

    #[test]
    fn keystream_empty_bulk_draw_consumes_nothing() {
        // an empty gen_vec must equal "no draw at all" even mid-byte —
        // otherwise a party handed an empty batch desyncs from peers
        let k = [7u8; 16];
        let mut a = Prf::new(k);
        let mut b = Prf::new(k);
        let _: Bit = a.gen();
        let _: Bit = b.gen();
        let v: Vec<Z64> = a.gen_vec(0);
        assert!(v.is_empty());
        assert_eq!(a.stream_bits(), b.stream_bits());
        assert_eq!(a.gen::<Z64>(), b.gen::<Z64>());
    }

    #[test]
    fn keystream_byte_draws_align_after_bits() {
        // byte draws round the cursor up to the next byte boundary — the
        // same deterministic rule at every party
        let k = [6u8; 16];
        let mut a = Prf::new(k);
        let mut b = Prf::new(k);
        let _: Bit = a.gen();
        let _: Bit = b.gen();
        assert_eq!(a.gen::<Z64>(), b.gen::<Z64>());
        assert_eq!(a.stream_bits(), b.stream_bits());
        assert_eq!(a.stream_bits(), 8 + 64, "1 bit aligned to a byte + 8 bytes");
    }

    #[test]
    fn hash_acc_order_and_framing() {
        let mut a = HashAcc::new();
        a.absorb(b"ab");
        a.absorb(b"c");
        let mut b = HashAcc::new();
        b.absorb(b"a");
        b.absorb(b"bc");
        assert_ne!(a.finalize(), b.finalize());

        let mut c = HashAcc::new();
        c.absorb_ring(&Z64(42));
        c.absorb_ring(&Bit(true));
        let mut d = HashAcc::new();
        d.absorb_ring(&Z64(42));
        d.absorb_ring(&Bit(true));
        assert_eq!(c.finalize(), d.finalize());
    }

    #[test]
    fn commitment_binding_hiding_smoke() {
        let r1 = [1u8; 16];
        let r2 = [2u8; 16];
        let c = Commitment::commit(b"key0", &r1);
        assert!(c.verify(b"key0", &r1));
        assert!(!c.verify(b"key1", &r1));
        assert!(!c.verify(b"key0", &r2));
        // same message, different randomness => different commitment
        assert_ne!(c, Commitment::commit(b"key0", &r2));
    }

    #[test]
    fn rng_deterministic_per_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        let mut c = Rng::seeded(43);
        let va: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..10).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn rng_uniform_in_range() {
        let mut r = Rng::seeded(1);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng::seeded(2);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
