//! Cryptographic substrate (paper Appendix A).
//!
//! * [`Prf`] — the shared-key pseudo-random function `F : {0,1}^κ × {0,1}^κ → X`
//!   used by `F_setup`-established keys for non-interactive correlated
//!   randomness. Instantiated as fixed-key-free AES-128 over a counter, the
//!   standard choice in the high-throughput honest-majority line of work
//!   (Araki et al.) that Trident builds on.
//! * [`hash_digest`] / [`HashAcc`] — the collision-resistant hash `H()`
//!   (SHA-256, as in §VI) with an *accumulating* variant used to batch many
//!   consistency checks into a single digest exchange — this is the
//!   amortization every communication lemma in Appendices B–D relies on.
//! * [`Commitment`] — hash-based commitments for the garbled world's key
//!   delivery (`Π_Sh^G`, Fig. 6).
//! * [`Rng`] — a fast, seedable local RNG (xoshiro256**) for dealer/test
//!   randomness. NOT used for shared randomness (that is the PRF's job).

pub mod aes128;
pub mod sha256;

use aes128::Aes128;
use sha256::Sha256;

use crate::ring::Ring;

/// κ = 128-bit computational security parameter (paper §IV-A).
pub const KAPPA_BYTES: usize = 16;

/// Key type for PRFs and garbling: 128-bit.
pub type Key = [u8; 16];

/// 256-bit hash digest.
pub type Digest32 = [u8; 32];

/// AES-128-based PRF with a monotone counter.
///
/// Two parties holding the same key and drawing the same number of elements
/// in the same order obtain identical streams — the mechanism behind every
/// "parties in P \ {P_j} together sample λ_{v,j}" step.
#[derive(Clone)]
pub struct Prf {
    cipher: Aes128,
    counter: u128,
}

impl std::fmt::Debug for Prf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Prf(ctr={})", self.counter)
    }
}

impl Prf {
    pub fn new(key: Key) -> Self {
        Prf { cipher: Aes128::new(key), counter: 0 }
    }

    /// Next 16-byte pseudorandom block.
    #[inline]
    pub fn next_block(&mut self) -> [u8; 16] {
        let block = self.counter.to_le_bytes();
        self.counter += 1;
        self.cipher.encrypt_block(block)
    }

    /// Sample one ring element.
    #[inline]
    pub fn gen<R: Ring>(&mut self) -> R {
        R::from_block(&self.next_block())
    }

    /// Sample `n` ring elements.
    pub fn gen_vec<R: Ring>(&mut self, n: usize) -> Vec<R> {
        (0..n).map(|_| self.gen()).collect()
    }

    /// Sample a κ-bit key (for garbled labels, offsets, …).
    #[inline]
    pub fn gen_key(&mut self) -> Key {
        self.next_block()
    }

    /// Number of blocks drawn so far — synchronization sanity check.
    pub fn position(&self) -> u128 {
        self.counter
    }
}

/// One-shot collision-resistant hash H(x).
pub fn hash_digest(data: &[u8]) -> Digest32 {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize().into()
}

/// Accumulating hash: absorb many values, emit one digest.
///
/// "the corresponding values can be appended and hashed, resulting in an
/// overall communication of only 3 ring elements" (§III-C) — protocols push
/// every to-be-verified value into one of these and exchange a single digest
/// at a flush point.
#[derive(Clone)]
pub struct HashAcc {
    h: Sha256,
    len: usize,
}

impl Default for HashAcc {
    fn default() -> Self {
        Self::new()
    }
}

impl HashAcc {
    pub fn new() -> Self {
        HashAcc { h: Sha256::new(), len: 0 }
    }

    pub fn absorb(&mut self, data: &[u8]) {
        // length-prefix every item so absorb("ab","c") != absorb("a","bc")
        self.h.update((data.len() as u64).to_le_bytes());
        self.h.update(data);
        self.len += 1;
    }

    pub fn absorb_ring<R: Ring>(&mut self, v: &R) {
        let mut buf = Vec::with_capacity(R::WIRE_BYTES);
        v.to_wire(&mut buf);
        self.absorb(&buf);
    }

    /// Number of absorbed items.
    pub fn items(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn finalize(self) -> Digest32 {
        self.h.finalize().into()
    }
}

/// Hash-based commitment `Com(m; r) = H(r ‖ m)` with 128-bit randomness.
///
/// Binding from collision resistance, hiding from the random prefix —
/// sufficient for the garbled-sharing key commitments of Fig. 6/8.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Commitment(pub Digest32);

impl Commitment {
    pub fn commit(msg: &[u8], rand: &Key) -> Commitment {
        let mut h = Sha256::new();
        h.update(rand);
        h.update(msg);
        Commitment(h.finalize().into())
    }

    /// Verify an opening (message + randomness).
    pub fn verify(&self, msg: &[u8], rand: &Key) -> bool {
        Commitment::commit(msg, rand) == *self
    }
}

/// xoshiro256** — fast local randomness for dealers, tests, and synthetic
/// data. Deterministic from a seed so every experiment is reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seeded(seed: u64) -> Rng {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// OS-seeded (non-deterministic) RNG.
    pub fn from_entropy() -> Rng {
        use std::time::{SystemTime, UNIX_EPOCH};
        let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap();
        Rng::seeded(t.as_nanos() as u64 ^ (std::process::id() as u64) << 32)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn gen<R: Ring>(&mut self) -> R {
        let mut block = [0u8; 16];
        block[..8].copy_from_slice(&self.next_u64().to_le_bytes());
        block[8..].copy_from_slice(&self.next_u64().to_le_bytes());
        R::from_block(&block)
    }

    pub fn gen_vec<R: Ring>(&mut self, n: usize) -> Vec<R> {
        (0..n).map(|_| self.gen()).collect()
    }

    pub fn gen_key(&mut self) -> Key {
        let mut k = [0u8; 16];
        k[..8].copy_from_slice(&self.next_u64().to_le_bytes());
        k[8..].copy_from_slice(&self.next_u64().to_le_bytes());
        k
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller (for synthetic datasets).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::{Bit, Z64};

    #[test]
    fn prf_deterministic_and_synced() {
        let k = [7u8; 16];
        let mut a = Prf::new(k);
        let mut b = Prf::new(k);
        for _ in 0..100 {
            assert_eq!(a.gen::<Z64>(), b.gen::<Z64>());
        }
        assert_eq!(a.position(), b.position());
    }

    #[test]
    fn prf_differs_across_keys() {
        let mut a = Prf::new([1u8; 16]);
        let mut b = Prf::new([2u8; 16]);
        let va: Vec<Z64> = a.gen_vec(8);
        let vb: Vec<Z64> = b.gen_vec(8);
        assert_ne!(va, vb);
    }

    #[test]
    fn prf_stream_not_constant() {
        let mut a = Prf::new([9u8; 16]);
        let v: Vec<Z64> = a.gen_vec(16);
        assert!(v.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn hash_acc_order_and_framing() {
        let mut a = HashAcc::new();
        a.absorb(b"ab");
        a.absorb(b"c");
        let mut b = HashAcc::new();
        b.absorb(b"a");
        b.absorb(b"bc");
        assert_ne!(a.finalize(), b.finalize());

        let mut c = HashAcc::new();
        c.absorb_ring(&Z64(42));
        c.absorb_ring(&Bit(true));
        let mut d = HashAcc::new();
        d.absorb_ring(&Z64(42));
        d.absorb_ring(&Bit(true));
        assert_eq!(c.finalize(), d.finalize());
    }

    #[test]
    fn commitment_binding_hiding_smoke() {
        let r1 = [1u8; 16];
        let r2 = [2u8; 16];
        let c = Commitment::commit(b"key0", &r1);
        assert!(c.verify(b"key0", &r1));
        assert!(!c.verify(b"key1", &r1));
        assert!(!c.verify(b"key0", &r2));
        // same message, different randomness => different commitment
        assert_ne!(c, Commitment::commit(b"key0", &r2));
    }

    #[test]
    fn rng_deterministic_per_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        let mut c = Rng::seeded(43);
        let va: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..10).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn rng_uniform_in_range() {
        let mut r = Rng::seeded(1);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng::seeded(2);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
