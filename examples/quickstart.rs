//! Quickstart: the smallest end-to-end Trident flow.
//!
//! Four parties (threads) are wired with pairwise channels and shared PRF
//! keys (`F_setup`); two of them contribute private fixed-point inputs; the
//! cluster evaluates a truncated product and a comparison without anyone
//! seeing the cleartext; the result is reconstructed at the output stage.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use trident::convert::bitext;
use trident::net::{NetProfile, Phase, P1, P2};
use trident::proto::{mult_tr, reconstruct, run_4pc, share};
use trident::ring::{Bit, FixedPoint};

fn main() {
    trident::runtime::pjrt::init_default();

    let run = run_4pc(NetProfile::lan(), 7, |ctx| {
        // --- input sharing (the only data-dependent thing owners do) ---
        let x = share(ctx, P1, (ctx.id() == P1).then_some(FixedPoint::encode(6.5)))?;
        let y = share(ctx, P2, (ctx.id() == P2).then_some(FixedPoint::encode(-2.25)))?;

        // --- secure compute: fixed-point multiply + sign test ---
        let xy = mult_tr(ctx, &x, &y)?; // [[x·y]], truncation folded in
        let neg = bitext(ctx, &xy)?; // [[msb(x·y)]]^B — is the product negative?

        // --- output reconstruction ---
        let prod = reconstruct(ctx, &xy)?;
        let sign = reconstruct(ctx, &neg)?;
        ctx.flush_verify()?;
        Ok((prod, sign))
    });

    let (outs, report) = run.expect_ok();
    let (prod, sign) = outs[0];
    println!("6.5 × -2.25       = {}", FixedPoint::decode(prod));
    println!("product negative? = {}", sign == Bit(true));
    println!();
    println!("-- what the meter saw --");
    println!("offline value bits : {}", report.value_bits[Phase::Offline as usize]);
    println!("online  value bits : {}", report.value_bits[Phase::Online as usize]);
    println!("online  rounds     : {}", report.rounds[Phase::Online as usize]);
    println!("simulated LAN time : {:.3} ms", report.online_latency() * 1e3);
    println!("P0 online time     : {:.3} ms (nonzero only for input/output stages)", report.party_time[1][0] * 1e3);
    // Π_MultTr's probabilistic truncation can be off by ≤2 ulp (2^-13)
    assert!((FixedPoint::decode(prod) - 6.5 * -2.25).abs() < 0.001);
    assert_eq!(sign, Bit(true));
    println!("\nquickstart OK");
}
