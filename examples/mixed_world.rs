//! Mixed-world tour (§IV): one value travels Arithmetic → Boolean → Garbled
//! → back to Arithmetic, exercising every conversion the framework offers,
//! with the metered costs printed next to the paper's Table I/IX claims.
//!
//! ```sh
//! cargo run --release --example mixed_world
//! ```

use trident::convert::{a2b, a2g, b2a, bit2a, bitext, g2a};
use trident::net::{NetProfile, Phase, P1, P3};
use trident::proto::{reconstruct, run_4pc, share};
use trident::ring::Z64;

fn main() {
    trident::runtime::pjrt::init_default();
    let secret: i64 = -123_456_789;

    let run = run_4pc(NetProfile::lan(), 11, move |ctx| {
        // arithmetic world
        let a = share(ctx, P1, (ctx.id() == P1).then_some(Z64::from(secret)))?;

        // A2B: to boolean shares (PPA subtractor, log ℓ rounds)
        let bits = a2b(ctx, &a)?;

        // B2A: straight back in ONE round (the 7× round win over ABY3)
        let back = b2a(ctx, &bits)?;

        // A2G: into the garbled world; G2A: back again
        let garbled = a2g(ctx, &back)?;
        let back2 = g2a(ctx, &garbled)?;

        // a comparison via Π_BitExt and its arithmetic lift
        let msb = bitext(ctx, &back2)?;
        let msb_arith = bit2a(ctx, &msb)?;

        let v = reconstruct(ctx, &back2)?;
        let is_neg = reconstruct(ctx, &msb_arith)?;
        ctx.flush_verify()?;
        Ok((v, is_neg))
    });

    let (outs, report) = run.expect_ok();
    let (v, is_neg) = outs[0];
    println!("value after A→B→A→G→A round-trip: {}", v.as_i64());
    println!("sign bit (as ring element):       {}", is_neg.0);
    assert_eq!(v.as_i64(), secret);
    assert_eq!(is_neg, Z64(1));
    println!();
    println!("-- metered --");
    println!(
        "online:  {:>6} rounds, {:>9} value bits, {:>8} garbled bytes",
        report.rounds[Phase::Online as usize],
        report.value_bits[Phase::Online as usize],
        report.garbled_bytes[Phase::Online as usize],
    );
    println!(
        "offline: {:>6} rounds, {:>9} value bits, {:>8} garbled bytes",
        report.rounds[Phase::Offline as usize],
        report.value_bits[Phase::Offline as usize],
        report.garbled_bytes[Phase::Offline as usize],
    );
    // the garbled-division softmax (§VI-A.c): the heaviest mixed-world user
    let run2 = run_4pc(NetProfile::lan(), 12, |ctx| {
        let mut shares = Vec::new();
        for v in [1.0f64, 3.0] {
            shares.push(share(
                ctx,
                P1,
                (ctx.id() == P1).then_some(trident::ring::FixedPoint::encode(v)),
            )?);
        }
        let p = trident::ml::softmax::softmax_garbled(ctx, &shares)?;
        let p0 = reconstruct(ctx, &p[0])?;
        ctx.flush_verify()?;
        Ok(p0)
    });
    let (outs2, rep2) = run2.expect_ok();
    println!(
        "\ngarbled softmax([1, 3])[0] = {:.3} (want 0.25), {} KiB garbled tables",
        trident::ring::FixedPoint::decode(outs2[0]),
        rep2.garbled_bytes[Phase::Offline as usize] / 1024,
    );
    println!("mixed_world OK");
}
