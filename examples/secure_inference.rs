//! Secure prediction serving (the MLaaS scenario of §I): a model owner
//! shares trained weights once; clients stream query batches; the four
//! servers answer them with online latency independent of the feature
//! count (Π_DotP) and P0 asleep for the whole online phase.
//!
//! ```sh
//! cargo run --release --example secure_inference [batches]
//! ```

use trident::net::{NetProfile, Phase};

fn main() {
    let batches: usize =
        std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(6);
    trident::runtime::pjrt::init_default();

    trident::coordinator::serve_cli(batches);

    // latency breakdown across the paper's four models, LAN vs WAN
    println!("\nper-model online prediction latency (d=784, B=100):");
    for model in ["linreg", "logreg", "nn"] {
        let lan = trident::bench::measure_predict(NetProfile::lan(), model, 784, 100);
        let wan = trident::bench::measure_predict(NetProfile::wan(), model, 784, 100);
        println!(
            "  {model:<6}  LAN {:>8.2} ms   WAN {:>6.2} s   (rounds {}, P0 online {:.1} ms)",
            lan.online_latency() * 1e3,
            wan.online_latency(),
            lan.online_rounds(),
            lan.report.party_time[Phase::Online as usize][0] * 1e3,
        );
    }
    println!("secure_inference OK");
}
