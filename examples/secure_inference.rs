//! Secure prediction serving (the MLaaS scenario of §I): a model owner
//! shares trained weights once; clients stream queries; the four servers
//! answer them with online latency independent of the feature count
//! (Π_DotP) and P0 asleep for the whole online phase.
//!
//! This example drives the real serving engine (`trident::serve`): the
//! offline pool is pre-stocked with truncation pairs, concurrent queries
//! are coalesced into cross-request batches (one protocol round-trip per
//! wave), and every response is verified before release. The same workload
//! is replayed through the seed-style per-query inline path for contrast.
//!
//! ```sh
//! cargo run --release --example secure_inference [queries]
//! ```

use trident::coordinator::ServeCliOpts;
use trident::net::{NetProfile, Phase};
use trident::sched::TenantSpec;
use trident::serve::{serve, serve_multi, MultiServeConfig, PoolMode, ServeConfig};

fn main() {
    let queries: usize =
        std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(16);
    trident::runtime::pjrt::init_default();

    // the CLI-level summary: keyed pool vs scalar pool vs inline
    trident::coordinator::serve_cli(ServeCliOpts { queries, ..ServeCliOpts::default() });

    // keyed-pool batch serving with a ReLU output layer, in detail. Since
    // the nonlinear pool landed, the whole warm wave — share, Π_MatMulTr,
    // ReLU, reconstruct — is offline-silent: the ReluCorr bundle carries
    // the bitext masks, the pre-exchanged γ of the r·v product and the
    // pre-checked Π_BitInj material, so no offline-phase message is left
    // to send per request.
    println!("\nkeyed-pool ReLU serving (d=128, 4-row queries, coalesce 8):");
    let cfg = ServeConfig {
        d: 128,
        rows_per_query: 4,
        queries,
        coalesce: 8,
        mode: PoolMode::Keyed,
        low_water: 1,
        high_water: 2,
        relu: true,
        seed: 42,
    };
    let s = serve(NetProfile::lan(), cfg);
    println!(
        "  {} queries in {} batches: {:.3} ms/query online, {} online rounds total",
        s.queries,
        s.batches,
        s.per_query_latency() * 1e3,
        s.online_rounds,
    );
    println!(
        "  offline (refill fills, between waves): {:.1} KiB under Phase::Offline; \
         in-wave offline msgs: {} (mat {} | relu {})",
        s.offline_value_bits as f64 / 8.0 / 1024.0,
        s.offline_msgs_in_waves,
        s.offline_msgs_matmul,
        s.offline_msgs_relu,
    );
    if let Some(ps) = s.pool_stats {
        println!(
            "  pool: {} hits, {} misses; refill {} keyed bundles over {} ticks",
            ps.hits(),
            ps.misses(),
            s.refill_mat_items,
            s.refill_ticks,
        );
    }

    // multi-tenant serving: two resident models with different priorities
    // behind one cluster — the sched subsystem (model registry with
    // per-tenant keyed pools, deadline/priority queue, weighted
    // round-robin wave planner) decides whose wave runs next
    println!("\nmulti-tenant serving (2 resident models, different priorities, WRR 2:1):");
    let mut fast = TenantSpec::new("fast", 1, 64, queries, 4);
    fast.weight = 2;
    fast.class = 0; // highest priority
    let mut bulk = TenantSpec::new("bulk", 2, 64, queries, 4);
    bulk.weight = 1;
    bulk.class = 1; // lower priority; aging keeps it from starving
    bulk.deadline_ticks = Some(8);
    let mcfg = MultiServeConfig {
        tenants: vec![fast, bulk],
        mode: PoolMode::Keyed,
        low_water: 1,
        high_water: 2,
        age_every: 2,
        seed: 42,
    };
    let ms = serve_multi(NetProfile::lan(), mcfg);
    print!("{}", trident::bench::tenant_table(&ms));
    println!(
        "  warm waves offline-silent per tenant: {}",
        if ms.offline_msgs_in_waves == 0 { "yes" } else { "NO" },
    );

    // latency breakdown across the paper's models, LAN vs WAN
    println!("\nper-model online prediction latency (d=784, B=100):");
    for model in ["linreg", "logreg", "nn"] {
        let lan = trident::bench::measure_predict(NetProfile::lan(), model, 784, 100);
        let wan = trident::bench::measure_predict(NetProfile::wan(), model, 784, 100);
        println!(
            "  {model:<6}  LAN {:>8.2} ms   WAN {:>6.2} s   (rounds {}, P0 online {:.1} ms)",
            lan.online_latency() * 1e3,
            wan.online_latency(),
            lan.online_rounds(),
            lan.report.party_time[Phase::Online as usize][0] * 1e3,
        );
    }
    println!("secure_inference OK");
}
