//! End-to-end driver (DESIGN.md §5): secure training of the paper's NN on a
//! synthetic MNIST-shaped workload, logging the loss curve — all layers of
//! the stack compose: JAX/Pallas AOT artifacts (when built) execute the
//! party-local matmuls via PJRT inside the rust 4PC protocols over the
//! metered network.
//!
//! ```sh
//! make artifacts && cargo run --release --example secure_training [iters] [batch] [features]
//! ```
//!
//! Defaults keep the run to ~a minute (a reduced 784-64-32-10 network at
//! batch 32); pass e.g. `200 128 784` for the paper's full shape. The run is
//! recorded in EXPERIMENTS.md §E2E.

fn main() {
    let args: Vec<usize> =
        std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let iters = args.first().copied().unwrap_or(30);
    let batch = args.get(1).copied().unwrap_or(32);
    let d = args.get(2).copied().unwrap_or(128);

    let pjrt = trident::runtime::pjrt::init_default();
    println!("PJRT artifacts: {}", if pjrt { "enabled" } else { "native fallback" });

    let losses = trident::coordinator::train_cli("nn", iters, batch, d);
    let first = losses.first().copied().unwrap_or(f64::NAN);
    let last = losses.last().copied().unwrap_or(f64::NAN);
    println!("\nloss: {first:.5} → {last:.5} over {iters} secure iterations");
    assert!(last < first, "training must make progress");
    println!("secure_training OK");
}
