"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes (including ragged fallbacks and tile boundaries)
and value regimes (full-range u64 → wrap-around is exercised constantly).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import masked_matmul as mm
from compile.kernels import ref

RNG = np.random.default_rng(1234)


def rand_u64(shape, full_range=True):
    hi = 2**64 - 1 if full_range else 2**20
    return RNG.integers(0, hi, shape, dtype=np.uint64)


def mk_args(a, b, c, full_range=True):
    return (
        rand_u64((a, b), full_range),
        rand_u64((b, c), full_range),
        rand_u64((a, b), full_range),
        rand_u64((b, c), full_range),
        rand_u64((a, c), full_range),
        rand_u64((a, c), full_range),
    )


dims = st.sampled_from([1, 2, 3, 7, 8, 16, 31, 64, 128, 130, 256])


@settings(max_examples=25, deadline=None)
@given(a=dims, b=dims, c=dims)
def test_masked_matmul_matches_ref(a, b, c):
    args = mk_args(a, b, c)
    out = np.array(mm.masked_matmul(*args))
    want = np.array(ref.masked_matmul_ref(*args))
    np.testing.assert_array_equal(out, want)


@settings(max_examples=20, deadline=None)
@given(a=dims, b=dims, c=dims)
def test_gemm_matches_ref(a, b, c):
    x, y = rand_u64((a, b)), rand_u64((b, c))
    np.testing.assert_array_equal(np.array(mm.gemm(x, y)), np.array(ref.gemm_ref(x, y)))


@settings(max_examples=10, deadline=None)
@given(a=st.sampled_from([8, 64, 128]), b=st.sampled_from([8, 128]), c=st.sampled_from([8, 128]))
def test_limb_decomposition_matches(a, b, c):
    args = mk_args(a, b, c)
    out = np.array(mm.masked_matmul_limbs(*args))
    want = np.array(ref.masked_matmul_ref(*args))
    np.testing.assert_array_equal(out, want)


def test_gamma_matmul():
    a, b, c = 16, 32, 8
    lx, lx1 = rand_u64((a, b)), rand_u64((a, b))
    ly, ly1 = rand_u64((b, c)), rand_u64((b, c))
    mask = rand_u64((a, c))
    out = np.array(mm.gamma_matmul(lx, lx1, ly, ly1, mask))
    want = np.array(ref.gamma_matmul_ref(lx, lx1, ly, ly1, mask))
    np.testing.assert_array_equal(out, want)


def test_wraparound_exactness():
    """Products near 2^64 must wrap exactly (mod-2^64 semantics)."""
    a = np.full((4, 4), 2**63 + 12345, dtype=np.uint64)
    b = np.full((4, 4), 3, dtype=np.uint64)
    g = np.zeros((4, 4), dtype=np.uint64)
    out = np.array(mm.masked_matmul(a, b, g, b, g, g))
    ref_int = -(4 * ((2**63 + 12345) * 3)) % 2**64
    assert (out == np.uint64(ref_int)).all()


def test_tile_boundary_identical_to_fallback():
    """128-divisible shapes take the Pallas path; 129 the fallback — both
    must agree with the oracle."""
    for dim in (128, 129):
        args = mk_args(dim, 128, 128)
        np.testing.assert_array_equal(
            np.array(mm.masked_matmul(*args)),
            np.array(ref.masked_matmul_ref(*args)),
        )


@pytest.mark.parametrize("tile", [32, 64, 128])
def test_tile_parameter_sweep(tile):
    args = mk_args(128, 128, 128)
    out = np.array(mm.masked_matmul(*args, tile=tile))
    want = np.array(ref.masked_matmul_ref(*args))
    np.testing.assert_array_equal(out, want)
