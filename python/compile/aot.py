"""AOT lowering: JAX/Pallas graphs → HLO **text** artifacts for the rust
PJRT runtime.

HLO text — not `.serialize()`d HloModuleProto — is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)


def to_hlo_text(fn, arg_specs) -> str:
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    total = 0
    for name, fn, specs in model.artifact_specs():
        if args.only and args.only not in name:
            continue
        text = to_hlo_text(fn, specs)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        total += 1
        print(f"wrote {path} ({len(text)} chars)")
    print(f"{total} artifacts")


if __name__ == "__main__":
    main()
