"""Layer-2 JAX graphs: the party-local compute of Trident's protocol phases.

Each graph is a pure jax function over uint64 ring tensors that calls the
L1 Pallas kernels (`kernels/masked_matmul.py`). `aot.py` lowers them once to
HLO text; at runtime the rust coordinator executes the artifacts via PJRT
(`rust/src/runtime/pjrt.rs`) from inside `Π_DotP`/`Π_MultTr`'s local steps.
Python never runs on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import masked_matmul as k

jax.config.update("jax_enable_x64", True)


def masked_matmul_graph(lx, my, mx, ly, g, lz):
    """Online share computation `M' = Γ + Λz − Λx∘M_y − M_x∘Λy`.

    Returned as a 1-tuple (the rust loader unwraps `to_tuple1`).
    """
    return (k.masked_matmul(lx, my, mx, ly, g, lz),)


def gemm_graph(x, y):
    """Plain ring matmul `X ∘ Y` (the `M_x∘M_y` online term and offline γ
    building block)."""
    return (k.gemm(x, y),)


def gamma_graph(lx_j, lx_j1, ly_j, ly_j1, mask):
    """Offline γ-component `Λx_j∘(Λy_j+Λy_{j+1}) + Λx_{j+1}∘Λy_j + mask`."""
    return (k.gamma_matmul(lx_j, lx_j1, ly_j, ly_j1, mask),)


#: shapes lowered by `aot.py`: (name, fn, arg shapes)
def artifact_specs():
    u64 = jnp.uint64

    def s(*shape):
        return jax.ShapeDtypeStruct(shape, u64)

    specs = []
    # canonical ML shapes: NN layer-1 (B×784 ∘ 784×128), hidden, output,
    # linreg batches, and a small test shape.
    for (a, b, c) in [
        (8, 8, 8),
        (128, 784, 128),
        (128, 128, 128),
        (128, 128, 10),
        (128, 784, 1),
        (784, 128, 1),
        (256, 256, 256),
    ]:
        specs.append(
            (
                f"masked_matmul_{a}x{b}x{c}",
                masked_matmul_graph,
                (s(a, b), s(b, c), s(a, b), s(b, c), s(a, c), s(a, c)),
            )
        )
        specs.append((f"gemm_{a}x{b}x{c}", gemm_graph, (s(a, b), s(b, c))))
        specs.append(
            (
                f"gamma_{a}x{b}x{c}",
                gamma_graph,
                (s(a, b), s(a, b), s(b, c), s(b, c), s(a, c)),
            )
        )
    return specs
