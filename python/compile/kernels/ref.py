"""Pure-jnp oracles for the L1 kernels - the CORE correctness signal.

Everything is elementary jnp over uint64 so any discrepancy in the Pallas
kernels (tiling, accumulation, wrap-around) shows up in pytest.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def masked_matmul_ref(lx, my, mx, ly, g, lz):
    """Gamma + Lz - Lx@My - Mx@Ly (mod 2^64)."""
    return g + lz - (lx @ my + mx @ ly)


def gemm_ref(x, y):
    return x @ y


def gamma_matmul_ref(lx_j, lx_j1, ly_j, ly_j1, mask):
    return lx_j @ (ly_j + ly_j1) + lx_j1 @ ly_j + mask
