"""Layer-1 Pallas kernels: the party-local hot spot of Trident's online phase.

The fused *masked matmul* computes, over the ring Z_{2^64} (uint64 with
wrap-around — exactly what XLA's u64 ops give),

    M' = Γ + Λz − Λx ∘ M_y − M_x ∘ Λy,

which is the evaluator-local share `m'_{z,j}` of `Π_DotP`/`Π_MultTr` in
matrix form (paper Fig. 9/18). The γ-offline kernel computes

    Γ_j = Λx_j ∘ (Λy_j + Λy_{j+1}) + Λx_{j+1} ∘ Λy_j (+ mask).

TPU shaping (DESIGN.md §4): tiles of TILE×TILE with a revisiting-accumulator
grid (i, j, k) — the k-axis streams HBM→VMEM while the (i, j) output tile
stays resident. `interpret=True` is mandatory on this CPU-only image; the
BlockSpec structure is what a real Mosaic lowering would tile. A
limb-decomposed variant (`masked_matmul_limbs`) shows the MXU-friendly
int32-limb formulation and is validated against the same oracle.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

jax.config.update("jax_enable_x64", True)

# VMEM-sized tile (8 B/elt × 4 operands × 128² ≈ 512 KiB of residency).
TILE = 128


def _mm_kernel(lx_ref, my_ref, mx_ref, ly_ref, g_ref, lz_ref, o_ref, acc_ref, *, k_steps):
    """Fused dual-matmul tile kernel with a revisiting accumulator."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += lx_ref[...] @ my_ref[...] + mx_ref[...] @ ly_ref[...]

    @pl.when(k == k_steps - 1)
    def _fini():
        o_ref[...] = g_ref[...] + lz_ref[...] - acc_ref[...]


def masked_matmul(lx, my, mx, ly, g, lz, tile=TILE):
    """`Γ + Λz − Λx∘M_y − M_x∘Λy` via a tiled Pallas kernel (interpret)."""
    a, b = lx.shape
    b2, c = my.shape
    assert b == b2 and mx.shape == (a, b) and ly.shape == (b, c)
    assert g.shape == (a, c) and lz.shape == (a, c)
    ta, tb, tc = min(tile, a), min(tile, b), min(tile, c)
    if a % ta or b % tb or c % tc:
        # ragged shapes: fall back to the unfused expression (still one jit)
        return g + lz - (lx @ my + mx @ ly)
    grid = (a // ta, c // tc, b // tb)
    return pl.pallas_call(
        partial(_mm_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ta, tb), lambda i, j, k: (i, k)),  # Λx
            pl.BlockSpec((tb, tc), lambda i, j, k: (k, j)),  # M_y
            pl.BlockSpec((ta, tb), lambda i, j, k: (i, k)),  # M_x
            pl.BlockSpec((tb, tc), lambda i, j, k: (k, j)),  # Λy
            pl.BlockSpec((ta, tc), lambda i, j, k: (i, j)),  # Γ
            pl.BlockSpec((ta, tc), lambda i, j, k: (i, j)),  # Λz
        ],
        out_specs=pl.BlockSpec((ta, tc), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a, c), jnp.uint64),
        scratch_shapes=[pltpu.VMEM((ta, tc), jnp.uint64)],
        interpret=True,
    )(lx, my, mx, ly, g, lz)


def _gemm_kernel(x_ref, y_ref, o_ref, acc_ref, *, k_steps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += x_ref[...] @ y_ref[...]

    @pl.when(k == k_steps - 1)
    def _fini():
        o_ref[...] = acc_ref[...]


def gemm(x, y, tile=TILE):
    """Plain ring matmul `X ∘ Y` (u64, wrap-around) as a Pallas kernel."""
    a, b = x.shape
    b2, c = y.shape
    assert b == b2
    ta, tb, tc = min(tile, a), min(tile, b), min(tile, c)
    if a % ta or b % tb or c % tc:
        return x @ y
    grid = (a // ta, c // tc, b // tb)
    return pl.pallas_call(
        partial(_gemm_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ta, tb), lambda i, j, k: (i, k)),
            pl.BlockSpec((tb, tc), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((ta, tc), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a, c), jnp.uint64),
        scratch_shapes=[pltpu.VMEM((ta, tc), jnp.uint64)],
        interpret=True,
    )(x, y)


def gamma_matmul(lx_j, lx_j1, ly_j, ly_j1, mask):
    """Offline γ-component: `Λx_j∘(Λy_j+Λy_{j+1}) + Λx_{j+1}∘Λy_j + mask`."""
    return gemm(lx_j, ly_j + ly_j1) + gemm(lx_j1, ly_j) + mask


def masked_matmul_limbs(lx, my, mx, ly, g, lz):
    """MXU-honest limb decomposition (DESIGN.md §4): u64 operands split into
    four 16-bit limbs; limb products accumulate in u64 (on TPU: int32 MXU
    passes with u32 carries). Same output as :func:`masked_matmul`."""

    def limbs(v):
        return [(v >> jnp.uint64(16 * i)) & jnp.uint64(0xFFFF) for i in range(4)]

    def limb_mm(x, y):
        acc = jnp.zeros((x.shape[0], y.shape[1]), jnp.uint64)
        xl = limbs(x)
        yl = limbs(y)
        for i in range(4):
            for j in range(4):
                if i + j < 4:  # limbs beyond 2^64 vanish mod 2^64
                    prod = xl[i] @ yl[j]
                    acc = acc + (prod << jnp.uint64(16 * (i + j)))
        return acc

    return g + lz - (limb_mm(lx, my) + limb_mm(mx, ly))
